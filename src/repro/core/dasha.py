"""DASHA family (Algorithm 1) and DASHA-SYNC-MVR (Algorithm 2), verbatim.

Functional JAX: ``init(...) -> DashaState``; ``step(state, ...) -> DashaState``
is jit-able and carries the full per-node state stacked on axis 0 (vmap on a
single host; see optim/distributed.py for the sharded model-training
integration).

The four variants differ ONLY in the h-update (Alg. 1 line 8), exactly as in
the paper.  The message/aggregation lines 9-14 are shared and run through
:meth:`repro.compress.RoundCompressor.estimator_update`, which makes the
loop generic over execution backends (DESIGN.md §5): ``dense`` reference,
``sparse`` (messages travel as (indices, values) pairs and the aggregate
touches K << d coords), and ``fused`` (one Pallas HBM pass):

    m_i     = C_i(h_i^{t+1} - h_i^t - a (g_i^t - h_i^t))
    g_i    <- g_i + m_i
    g      <- g + (1/n) sum_i m_i

Invariant (tested): g^t == mean_i g_i^t at every t, for every backend.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.compress import as_round_compressor
from repro.core.node_compress import NodeCompressor
from repro.core.oracles import FiniteSumProblem, StochasticProblem


class DashaState(NamedTuple):
    x: jax.Array          # (d,)  server iterate
    g: jax.Array          # (d,)  server gradient estimator
    g_local: jax.Array    # (n,d) per-node g_i
    h_local: jax.Array    # (n,d) per-node h_i
    key: jax.Array
    t: jax.Array          # step counter
    bits_sent: jax.Array  # cumulative scalar coords sent per node (accounting)


@dataclasses.dataclass(frozen=True)
class DashaHyper:
    gamma: float                    # stepsize
    a: float                        # compressor momentum, 1/(2 omega + 1)
    variant: str = "dasha"          # dasha | page | mvr | sync_mvr
    b: float = 1.0                  # MVR momentum
    p: float = 1.0                  # PAGE / SYNC-MVR coin probability
    batch: int = 1                  # B
    batch_sync: int = 1             # B' (SYNC-MVR big batch)


# ---------------------------------------------------------------------------
# initialisation (Cor. 6.2 / 6.5: g_i^0 = h_i^0 = grad f_i(x^0); Cor. 6.8 /
# 6.10: minibatch of size B_init; zeros also allowed under PL)
# ---------------------------------------------------------------------------

def init(x0: jax.Array, n: int, key: jax.Array, *,
         problem: Optional[Any] = None, hyper: Optional[DashaHyper] = None,
         init_mode: str = "exact", batch_init: int = 1) -> DashaState:
    d = x0.shape[0]
    if init_mode == "zeros" or problem is None:
        h0 = jnp.zeros((n, d), x0.dtype)
        bits0 = 0.0
    elif init_mode == "exact":
        h0 = problem.full_grad(x0)
        bits0 = float(d)
    elif init_mode == "stoch":
        key, sub = jax.random.split(key)
        h0 = problem.stoch_grad(sub, x0, batch_init)
        bits0 = float(d)
    else:
        raise ValueError(init_mode)
    return DashaState(x=x0, g=jnp.mean(h0, 0), g_local=h0, h_local=h0,
                      key=key, t=jnp.zeros((), jnp.int32),
                      bits_sent=jnp.asarray(bits0, jnp.float32))


# ---------------------------------------------------------------------------
# h-updates (Alg. 1 line 8)
# ---------------------------------------------------------------------------

def _h_dasha(problem, key, hp, x_new, x_old, h):
    return problem.full_grad(x_new)


def _h_page(problem: FiniteSumProblem, key, hp: DashaHyper, x_new, x_old, h):
    k_coin, k_batch = jax.random.split(key)
    coin = jax.random.bernoulli(k_coin, hp.p)
    full = problem.full_grad(x_new)
    inc = h + problem.minibatch_diff(k_batch, x_new, x_old, hp.batch)
    return jnp.where(coin, full, inc)


def _h_mvr(problem: StochasticProblem, key, hp: DashaHyper, x_new, x_old, h):
    g_new, g_old = problem.stoch_grad_pair(key, x_new, x_old, hp.batch)
    return g_new + (1.0 - hp.b) * (h - g_old)


_H_UPDATES = {"dasha": _h_dasha, "page": _h_page, "mvr": _h_mvr}


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------

def step(state: DashaState, hp: DashaHyper, problem, comp) -> DashaState:
    """One communication round of Algorithm 1 (or Algorithm 2 for sync_mvr).

    ``comp``: a :class:`repro.compress.RoundCompressor` (or a legacy
    :class:`NodeCompressor`); its ``backend`` field selects dense / sparse /
    fused execution of lines 9-10 without changing the math."""
    rc = as_round_compressor(comp)
    key, k_h, k_c, k_coin = jax.random.split(state.key, 4)
    x_new = state.x - hp.gamma * state.g          # line 4 (server) + broadcast

    if hp.variant == "sync_mvr":
        return _step_sync_mvr(state, hp, problem, rc, x_new, key, k_h, k_c,
                              k_coin)

    h_new = _H_UPDATES[hp.variant](problem, k_h, hp, x_new, state.x,
                                   state.h_local)                     # line 8
    # lines 9-10: m_i = C_i(drift); g_i <- g_i + m_i (backend-dispatched)
    msgs, h_new, g_local = rc.estimator_update(k_c, h_new, state.h_local,
                                               state.g_local, hp.a)
    g = state.g + msgs.mean()                                         # line 14
    return DashaState(x=x_new, g=g, g_local=g_local, h_local=h_new, key=key,
                      t=state.t + 1,
                      bits_sent=state.bits_sent + rc.payload_per_node)


def _step_sync_mvr(state, hp, problem, rc, x_new, key, k_h, k_c, k_coin):
    """Algorithm 2.  With prob p all nodes send a FRESH uncompressed megabatch
    gradient (the synchronization step); otherwise a SARAH-style compressed
    drift message."""
    coin = jax.random.bernoulli(k_coin, hp.p)

    # -- sync branch (lines 9-11): h_i = fresh B' batch; m_i = g_i = h_i ----
    h_sync = problem.stoch_grad(k_h, x_new, hp.batch_sync)

    # -- compressed branch (lines 13-15): b=0 MVR (SARAH) + usual message ---
    g_pair_new, g_pair_old = problem.stoch_grad_pair(k_h, x_new, state.x,
                                                     hp.batch)
    h_inc = g_pair_new + (state.h_local - g_pair_old)
    msgs, h_inc, g_comp = rc.estimator_update(k_c, h_inc, state.h_local,
                                              state.g_local, hp.a)

    h_new = jnp.where(coin, h_sync, h_inc)
    g_local = jnp.where(coin, h_sync, g_comp)
    g = jnp.where(coin, jnp.mean(h_sync, 0), state.g + msgs.mean())
    d = state.x.shape[0]
    payload = jnp.where(coin, float(d), rc.payload_per_node)
    return DashaState(x=x_new, g=g, g_local=g_local, h_local=h_new, key=key,
                      t=state.t + 1, bits_sent=state.bits_sent + payload)


def run(state: DashaState, hp: DashaHyper, problem, comp: NodeCompressor,
        num_rounds: int, *, metric_every: int = 1, metric_fn=None):
    """Drive T rounds under jax.lax.scan; returns final state + metric trace.

    ``metric_fn(state) -> scalar`` (default: ||grad f(x)||^2 if the problem
    exposes an exact gradient).
    """
    if metric_fn is None:
        if hasattr(problem, "grad_f"):
            metric_fn = lambda s: jnp.sum(problem.grad_f(s.x) ** 2)
        elif getattr(problem, "true_grad", None) is not None:
            metric_fn = lambda s: jnp.sum(problem.true_grad(s.x) ** 2)
        else:
            metric_fn = lambda s: jnp.float32(0)

    def body(carry, _):
        new = step(carry, hp, problem, comp)
        return new, (metric_fn(new), new.bits_sent)

    final, (trace, bits) = jax.lax.scan(body, state, None, length=num_rounds)
    return final, trace, bits
