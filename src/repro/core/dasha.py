"""DASHA family (Algorithm 1) and DASHA-SYNC-MVR (Algorithm 2) — thin shim.

The paper-faithful flat research loop is now ONE instantiation of the
methods layer (DESIGN.md §7): the variant rules (the h-updates of Alg. 1
line 8) live in :mod:`repro.methods.rules`, the (n, d) state ops in
:class:`repro.methods.substrates.FlatSubstrate`, and the shared skeleton —
server step, compressed message, g_i update, aggregation, sync coin — in
:meth:`repro.methods.engine.Method.build`.  These entry points keep the
seed's signatures and are BIT-IDENTICAL to the seed loop (same RNG splits,
same arithmetic grouping):

    m_i     = C_i(h_i^{t+1} - h_i^t - a (g_i^t - h_i^t))
    g_i    <- g_i + m_i
    g      <- g + (1/n) sum_i m_i

Invariant (tested): g^t == mean_i g_i^t at every t, for every variant x
compression mode x execution backend.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.methods import FlatSubstrate, Hyper, Method, MethodState

#: unified state/hyper (aliases keep the seed's names importable)
DashaState = MethodState
DashaHyper = Hyper


def _substrate(problem, n: int, d: int) -> FlatSubstrate:
    return FlatSubstrate(problem=problem, n=n, d=d)


def _method(hp: DashaHyper, problem, comp, n: int, d: int) -> Method:
    return Method.build(hp.variant, comp, _substrate(problem, n, d), hp)


def init(x0: jax.Array, n: int, key: jax.Array, *,
         problem=None, hyper: Optional[DashaHyper] = None,
         init_mode: str = "exact", batch_init: int = 1) -> DashaState:
    """Cor. 6.2 / 6.5: g_i^0 = h_i^0 = grad f_i(x^0); Cor. 6.8 / 6.10:
    minibatch of size B_init; zeros also allowed under PL."""
    hp = hyper or DashaHyper(gamma=0.0, a=1.0)
    sub = _substrate(problem, n, x0.shape[0])
    # the compressor plays no role at init; identity keeps build() total
    m = Method.build(hp.variant, _identity(x0.shape[0], n), sub, hp)
    return m.init(x0, key, init_mode=init_mode, batch_init=batch_init)


def _identity(d: int, n: int):
    from repro.compress import make_round_compressor
    return make_round_compressor("identity", d, n)


def step(state: DashaState, hp: DashaHyper, problem, comp) -> DashaState:
    """One communication round of Algorithm 1 (or Algorithm 2 for
    sync_mvr).

    ``comp``: anything :func:`repro.compress.as_round_compressor` accepts —
    a :class:`repro.compress.RoundCompressor` or a legacy
    :class:`repro.compress.legacy.NodeCompressor` view; its ``backend``
    field selects dense / sparse / fused execution of lines 9-10 without
    changing the math."""
    n, d = state.g_local.shape
    return _method(hp, problem, comp, n, d).step(state)


def run(state: DashaState, hp: DashaHyper, problem, comp,
        num_rounds: int, *, metric_every: int = 1, metric_fn=None):
    """Drive T rounds under jax.lax.scan; returns (final state, metric
    trace, cumulative payload trace).

    ``comp`` is any ``RoundCompressor``-coercible compressor (see
    :func:`step`); ``metric_fn(state) -> scalar`` defaults to
    ||grad f(x)||^2 when the problem exposes an exact gradient."""
    n, d = state.g_local.shape
    return _method(hp, problem, comp, n, d).run(
        state, num_rounds, metric_every=metric_every, metric_fn=metric_fn)
