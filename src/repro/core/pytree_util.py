"""Pytree <-> flat-vector plumbing for the optimizer core.

DASHA's math lives on flat d-vectors; model params are pytrees.  We centralise
ravel/unravel here so the optimizer core stays dimension-agnostic.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

PyTree = Any


def ravel(tree: PyTree) -> Tuple[jax.Array, Callable[[jax.Array], PyTree]]:
    flat, unravel = ravel_pytree(tree)
    return flat, unravel


def tree_dim(tree: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like_flat(tree: PyTree) -> jax.Array:
    return jnp.zeros((tree_dim(tree),), jnp.float32)
