"""Theory-prescribed hyperparameters and complexity formulas (Section 6).

Everything here keeps the paper's exact constants — the benchmarks use these
(only the stepsize may be fine-tuned over powers of two, exactly as in
Appendix A of the paper).
"""
from __future__ import annotations

import dataclasses
import math

# a = 1/(2 omega + 1) (Theorems 6.1 / 6.4 / 6.7); single definition lives
# with the omega calculus in the compression spec layer.
from repro.compress.spec import momentum_a  # noqa: F401


def gamma_dasha(L: float, L_hat: float, omega: float, n: int) -> float:
    """Theorem 6.1: gamma <= (L + sqrt(16 w (2w+1)/n) * L_hat)^{-1}."""
    return 1.0 / (L + math.sqrt(16.0 * omega * (2 * omega + 1) / n) * L_hat)


def gamma_dasha_page(L: float, L_hat: float, L_max: float, omega: float,
                     n: int, B: int, p: float) -> float:
    """Theorem 6.4."""
    inner = (48.0 * omega * (2 * omega + 1) / n
             * ((1 - p) * L_max**2 / B + L_hat**2)
             + 2.0 * (1 - p) * L_max**2 / (p * n * B))
    return 1.0 / (L + math.sqrt(inner))


def gamma_dasha_mvr(L: float, L_hat: float, L_sigma: float, omega: float,
                    n: int, B: int, b: float) -> float:
    """Theorem 6.7."""
    inner = (96.0 * omega * (2 * omega + 1) / n
             * ((1 - b) ** 2 * L_sigma**2 / B + L_hat**2)
             + 4.0 * (1 - b) ** 2 * L_sigma**2 / (b * n * B))
    return 1.0 / (L + math.sqrt(inner))


def gamma_sync_mvr(L: float, L_hat: float, L_sigma: float, omega: float,
                   n: int, B: int, p: float) -> float:
    """Theorem H.19."""
    inner = (12.0 * omega * (2 * omega + 1) * (1 - p) / n
             * (L_sigma**2 / B + L_hat**2)
             + 2.0 * (1 - p) * L_sigma**2 / (p * n * B))
    return 1.0 / (L + math.sqrt(inner))


def page_p(B: int, m: int) -> float:
    """Corollary 6.5: p = B / (m + B)."""
    return B / (m + B)


def mvr_b(omega: float, n: int, B: int, eps: float, sigma2: float) -> float:
    """Corollary 6.8: b = Theta(min{ (1/w) sqrt(n eps B / s2), n eps B / s2 })."""
    if sigma2 == 0:
        return 1.0
    r = n * eps * B / sigma2
    b = min(math.sqrt(r) / max(omega, 1e-12), r)
    return max(min(b, 1.0), 1e-8)


def sync_mvr_p(zeta: float, d: int, n: int, B: int, eps: float,
               sigma2: float) -> float:
    """Corollary 6.10: p = min{zeta/d, n eps B / sigma^2}."""
    if sigma2 == 0:
        return zeta / d
    return max(min(zeta / d, n * eps * B / sigma2), 1e-8)


def marina_p(zeta: float, d: int) -> float:
    """MARINA's sync probability p = zeta_C / d (Gorbunov et al. 2021)."""
    return zeta / d


def gamma_marina(L: float, omega: float, n: int, p: float) -> float:
    """MARINA stepsize (Gorbunov et al. 2021, Theorem 2.1):
    gamma <= (L (1 + sqrt((1-p) omega / (p n))))^{-1}."""
    return 1.0 / (L * (1.0 + math.sqrt((1.0 - p) * omega / (p * n))))


# ---------------------------------------------------------------------------
# Table 1 (general nonconvex) communication-round counts, up to constants.
# These power benchmarks/table1_complexity.py.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    eps: float
    n: int
    omega: float
    delta0: float = 1.0      # f(x0) - f*
    L: float = 1.0
    L_hat: float = 1.0
    L_max: float = 1.0
    L_sigma: float = 1.0
    m: int = 1
    B: int = 1
    sigma2: float = 0.0
    d: int = 1
    zeta: float = 1.0


def rounds_dasha(c: ProblemConstants) -> float:
    return c.delta0 * (c.L + c.omega / math.sqrt(c.n) * c.L_hat) / c.eps


def rounds_marina(c: ProblemConstants) -> float:
    return (1.0 + c.omega / math.sqrt(c.n)) * c.L * c.delta0 / c.eps


def rounds_dasha_page(c: ProblemConstants) -> float:
    t = (c.L + c.omega / math.sqrt(c.n) * c.L_hat
         + (c.omega / math.sqrt(c.n) + math.sqrt(c.m / (c.n * c.B)))
         * c.L_max / math.sqrt(c.B))
    return c.delta0 * t / c.eps


def rounds_vr_marina(c: ProblemConstants) -> float:
    return ((1.0 + c.omega / math.sqrt(c.n)) / c.eps
            + math.sqrt((1.0 + c.omega) * c.m) / (c.eps * math.sqrt(c.n) * c.B)
            ) * c.L_max * c.delta0


def rounds_dasha_mvr(c: ProblemConstants) -> float:
    t = (c.L + c.omega / math.sqrt(c.n) * c.L_hat
         + (c.omega / math.sqrt(c.n)
            + math.sqrt(c.sigma2 / (c.eps * c.n**2 * c.B)))
         * c.L_sigma / math.sqrt(c.B))
    return c.delta0 * t / c.eps + c.sigma2 / (c.n * c.eps * c.B)


def rounds_sync_mvr(c: ProblemConstants) -> float:
    t = (c.L + c.omega / math.sqrt(c.n) * c.L_hat
         + (c.omega / math.sqrt(c.n) + math.sqrt(c.d / (c.zeta * c.n))
            + math.sqrt(c.sigma2 / (c.eps * c.n**2 * c.B)))
         * c.L_sigma / math.sqrt(c.B))
    return c.delta0 * t / c.eps + c.sigma2 / (c.n * c.eps * c.B)


def rounds_vr_marina_online(c: ProblemConstants) -> float:
    return ((1.0 + c.omega / math.sqrt(c.n)) * c.L_sigma * c.delta0 / c.eps
            + c.sigma2 / (c.eps * c.n * c.B)
            + math.sqrt(1.0 + c.omega) * math.sqrt(c.sigma2)
            * c.L_sigma * c.delta0 / (c.eps**1.5 * c.n * c.B))


def comm_complexity(rounds: float, zeta: float, d: int) -> float:
    """O(d + zeta_C * T) coordinates per node."""
    return d + zeta * rounds


def oracle_complexity_page(rounds: float, m: int, B: int) -> float:
    """Corollary 6.5: O(m + B T)."""
    return m + B * rounds
