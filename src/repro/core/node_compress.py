"""DEPRECATED seed-era shim: NodeCompressor lives in :mod:`repro.compress`.

The (n, d) execution modes (independent | shared_coords | permk) are
documented in DESIGN.md §3; the backend column (dense | sparse | fused) in
§5.  Construct :class:`repro.compress.RoundCompressor` directly (or via
:func:`repro.compress.make_round_compressor`) instead.
"""
import warnings

warnings.warn(
    "repro.core.node_compress is a deprecated seed-era shim; use "
    "repro.compress.RoundCompressor / make_round_compressor instead.",
    DeprecationWarning, stacklevel=2)

from repro.compress.legacy import NodeCompressor  # noqa: F401,E402
