"""Back-compat shim: NodeCompressor now lives in :mod:`repro.compress`.

The (n, d) execution modes (independent | shared_coords | permk) are
documented in DESIGN.md §3; the backend column (dense | sparse | fused) in
§5.  New code should construct :class:`repro.compress.RoundCompressor`
directly (or via :func:`repro.compress.make_round_compressor`).
"""
from repro.compress.legacy import NodeCompressor  # noqa: F401
