"""Per-node compression of the (n, d) message matrix.

Three execution modes (see DESIGN.md §3):

* ``independent`` — paper-faithful Assumption 1.2: each node draws its own key.
* ``shared_coords`` — all nodes share one RandK index set per round, so the
  aggregated message is K-sparse with a *common* support: on a mesh the
  all-reduce moves K floats instead of d (beyond-paper TPU adaptation).
* ``permk`` — PermK partitioning; node i's support is block i of a shared
  per-round permutation (maps to reduce-scatter on a mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor, PermK, RandK


@dataclasses.dataclass(frozen=True)
class NodeCompressor:
    base: Compressor
    n: int
    mode: str = "independent"  # independent | shared_coords | permk

    @property
    def omega(self) -> float:
        return self.base.omega

    @property
    def payload_per_node(self) -> float:
        return self.base.expected_density

    def __call__(self, key: jax.Array, deltas: jax.Array) -> jax.Array:
        """deltas: (n, d) -> messages m_i: (n, d) (dense representation)."""
        if self.mode == "independent":
            keys = jax.random.split(key, self.n)
            return jax.vmap(self.base)(keys, deltas)
        if self.mode == "shared_coords":
            assert isinstance(self.base, RandK), "shared_coords needs RandK"
            mask = self.base.mask(key).astype(deltas.dtype)
            scale = self.base.d / self.base.k
            return deltas * mask[None, :] * scale
        if self.mode == "permk":
            assert isinstance(self.base, PermK)
            d = deltas.shape[-1]
            perm = jax.random.permutation(key, d)
            block = d // self.n
            sel = perm.reshape(self.n, block)  # node i -> its coords
            masks = jnp.zeros((self.n, d), deltas.dtype)
            masks = jax.vmap(lambda s: jnp.zeros((d,), deltas.dtype).at[s].set(1))(sel)
            return deltas * masks * self.n
        raise ValueError(self.mode)
