"""MARINA / VR-MARINA / VR-MARINA (online) baselines (Gorbunov et al., 2021).

Implemented because every paper figure compares against them.  MARINA's server
keeps a single estimator g; with probability p ALL nodes upload an
uncompressed gradient simultaneously (the synchronization DASHA removes),
otherwise compressed gradient differences:

    g^{t+1} = (1/n) sum_i [ c=1 ?  G_i(x^{t+1})
                                :  g^t + C_i(G_i(x^{t+1}) - G_i(x^t)) ]

where G_i is the oracle (full grad / minibatch-diff / online minibatch-diff).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.compress import as_round_compressor


class MarinaState(NamedTuple):
    x: jax.Array
    x_prev: jax.Array
    g: jax.Array
    key: jax.Array
    t: jax.Array
    bits_sent: jax.Array


@dataclasses.dataclass(frozen=True)
class MarinaHyper:
    gamma: float
    p: float                     # sync probability
    variant: str = "marina"      # marina | vr | vr_online
    batch: int = 1
    batch_sync: int = 1          # megabatch B' for vr_online sync step


def init(x0: jax.Array, key: jax.Array, problem) -> MarinaState:
    g0 = jnp.mean(problem.full_grad(x0), 0) if hasattr(problem, "full_grad") \
        else jnp.mean(problem.stoch_grad(key, x0, 64), 0)
    return MarinaState(x=x0, x_prev=x0, g=g0, key=key,
                       t=jnp.zeros((), jnp.int32),
                       bits_sent=jnp.asarray(float(x0.shape[0]), jnp.float32))


def step(state: MarinaState, hp: MarinaHyper, problem, comp) -> MarinaState:
    rc = as_round_compressor(comp)
    key, k_coin, k_b, k_c = jax.random.split(state.key, 4)
    x_new = state.x - hp.gamma * state.g
    coin = jax.random.bernoulli(k_coin, hp.p)
    d = state.x.shape[0]

    if hp.variant == "marina":
        sync = problem.full_grad(x_new)                      # (n, d)
        diff = problem.full_grad(x_new) - problem.full_grad(state.x)
    elif hp.variant == "vr":
        sync = problem.full_grad(x_new)
        diff = problem.minibatch_diff(k_b, x_new, state.x, hp.batch)
    elif hp.variant == "vr_online":
        sync = problem.stoch_grad(k_b, x_new, hp.batch_sync)
        gn, go = problem.stoch_grad_pair(k_b, x_new, state.x, hp.batch)
        diff = gn - go
    else:
        raise ValueError(hp.variant)

    msgs = rc.compress(k_c, diff)          # dense / sparse wire format
    g_comp = state.g + msgs.mean()
    g_sync = jnp.mean(sync, 0)
    g = jnp.where(coin, g_sync, g_comp)
    payload = jnp.where(coin, float(d), rc.payload_per_node)
    return MarinaState(x=x_new, x_prev=state.x, g=g, key=key, t=state.t + 1,
                       bits_sent=state.bits_sent + payload)


def run(state: MarinaState, hp: MarinaHyper, problem, comp,
        num_rounds: int, metric_fn=None):
    if metric_fn is None:
        if hasattr(problem, "grad_f"):
            metric_fn = lambda s: jnp.sum(problem.grad_f(s.x) ** 2)
        elif getattr(problem, "true_grad", None) is not None:
            metric_fn = lambda s: jnp.sum(problem.true_grad(s.x) ** 2)
        else:
            metric_fn = lambda s: jnp.float32(0)

    def body(carry, _):
        new = step(carry, hp, problem, comp)
        return new, (metric_fn(new), new.bits_sent)

    final, (trace, bits) = jax.lax.scan(body, state, None, length=num_rounds)
    return final, trace, bits
