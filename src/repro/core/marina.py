"""MARINA / VR-MARINA / VR-MARINA (online) baselines — thin shim.

MARINA (Gorbunov et al., 2021) is now the fifth rule in the methods
registry (DESIGN.md §7): track h_i^t = G_i(x^t) by telescoping the oracle
difference, force the compressor momentum a = 0 so the drift is exactly
C_i(G_i(x^{t+1}) - G_i(x^t)), and flip the probability-p coin for the
uncompressed synchronization round (the very synchronization DASHA
removes):

    g^{t+1} = (1/n) sum_i [ c=1 ?  G_i(x^{t+1})
                                :  g^t + C_i(G_i(x^{t+1}) - G_i(x^t)) ]

The three seed variants map onto the one rule through the oracle:
``marina`` uses exact full-gradient differences (batch=0), ``vr`` a
shared-sample minibatch difference, and ``vr_online`` the stochastic
same-sample pair — dispatch that now lives in the substrate's oracle ops,
not here.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.methods import FlatSubstrate, Hyper, Method, MethodState

#: unified method state (x, g, g_local, h_local, ..., bits_sent); h_local
#: carries G_i(x^t), which replaces the seed's explicit x_prev field
MarinaState = MethodState

_VARIANTS = ("marina", "vr", "vr_online")


@dataclasses.dataclass(frozen=True)
class MarinaHyper:
    gamma: float
    p: float                     # sync probability
    variant: str = "marina"      # marina | vr | vr_online
    batch: int = 1
    batch_sync: int = 1          # megabatch B' for vr_online sync step


def _hyper(hp: MarinaHyper) -> Hyper:
    if hp.variant not in _VARIANTS:
        raise ValueError(hp.variant)
    # batch=0 asks the oracle for the exact full-gradient difference
    batch = 0 if hp.variant == "marina" else hp.batch
    return Hyper(gamma=hp.gamma, a=0.0, variant="marina", p=hp.p,
                 batch=batch, batch_sync=hp.batch_sync)


def _check_oracle(problem, variant: str) -> None:
    """The seed dispatched on hp.variant and failed loudly on a mismatched
    oracle; keep that contract now that dispatch lives in the oracle ops."""
    if variant == "vr_online" and not hasattr(problem, "stoch_grad"):
        raise ValueError("variant='vr_online' needs a StochasticProblem-"
                         "style oracle (stoch_grad / stoch_grad_pair)")
    if variant in ("marina", "vr") and not hasattr(problem, "full_grad"):
        raise ValueError(f"variant={variant!r} needs a FiniteSumProblem-"
                         "style oracle (full_grad / minibatch_diff)")


def _method(hp: MarinaHyper, problem, comp, n: int, d: int) -> Method:
    _check_oracle(problem, hp.variant)
    sub = FlatSubstrate(problem=problem, n=n, d=d)
    return Method.build("marina", comp, sub, _hyper(hp))


def init(x0: jax.Array, key: jax.Array, problem) -> MarinaState:
    from repro.compress import make_round_compressor
    n = problem.n
    d = x0.shape[0]
    sub = FlatSubstrate(problem=problem, n=n, d=d)
    m = Method.build("marina", make_round_compressor("identity", d, n), sub,
                     Hyper(gamma=0.0, a=0.0, variant="marina"))
    mode = "exact" if hasattr(problem, "full_grad") else "stoch"
    return m.init(x0, key, init_mode=mode, batch_init=64)


def step(state: MarinaState, hp: MarinaHyper, problem, comp) -> MarinaState:
    n, d = state.g_local.shape
    return _method(hp, problem, comp, n, d).step(state)


def run(state: MarinaState, hp: MarinaHyper, problem, comp,
        num_rounds: int, metric_fn=None):
    n, d = state.g_local.shape
    return _method(hp, problem, comp, n, d).run(state, num_rounds,
                                                metric_fn=metric_fn)
