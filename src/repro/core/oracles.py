"""Gradient oracles for the three settings of Section 1.2.

Every oracle exposes per-node quantities as stacked ``(n, d)`` arrays (the node
axis is vmap-ed on CPU and shard_map-ed on a mesh).  Problems are supplied as a
per-sample loss ``loss(x, feat, label)``; data lives in ``(n, m, ...)`` arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FiniteSumProblem:
    """f_i(x) = (1/m) sum_j loss(x, a_ij, y_ij)   (eq. (2)).

    ``features``: (n, m, ...), ``labels``: (n, m, ...).
    """

    loss: Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
    features: jax.Array
    labels: jax.Array

    @property
    def n(self) -> int:
        return self.features.shape[0]

    @property
    def m(self) -> int:
        return self.features.shape[1]

    # -- function values -------------------------------------------------
    def f(self, x: jax.Array) -> jax.Array:
        """Global objective f(x) = (1/n) sum_i f_i(x)."""
        per = jax.vmap(lambda a, y: jnp.mean(
            jax.vmap(lambda aa, yy: self.loss(x, aa, yy))(a, y)))(
                self.features, self.labels)
        return jnp.mean(per)

    # -- oracles ----------------------------------------------------------
    def full_grad(self, x: jax.Array) -> jax.Array:
        """(n, d): exact nabla f_i(x) for every node."""
        gfun = jax.grad(self.loss)

        def node(a, y):
            return jnp.mean(jax.vmap(lambda aa, yy: gfun(x, aa, yy))(a, y), 0)

        return jax.vmap(node)(self.features, self.labels)

    def grad_f(self, x: jax.Array) -> jax.Array:
        return jnp.mean(self.full_grad(x), axis=0)

    def _sample_idx(self, key: jax.Array, batch: int) -> jax.Array:
        # i.i.d. WITH replacement, matching the paper's multiset I_i.
        return jax.random.randint(key, (self.n, batch), 0, self.m)

    def minibatch_grad(self, key: jax.Array, x: jax.Array,
                       batch: int) -> jax.Array:
        """(n, d): (1/B) sum_{j in I_i} nabla f_ij(x)."""
        idx = self._sample_idx(key, batch)
        gfun = jax.grad(self.loss)

        def node(a, y, ids):
            return jnp.mean(
                jax.vmap(lambda j: gfun(x, a[j], y[j]))(ids), 0)

        return jax.vmap(node)(self.features, self.labels, idx)

    def minibatch_diff(self, key: jax.Array, x_new: jax.Array,
                       x_old: jax.Array, batch: int) -> jax.Array:
        """(n, d): (1/B) sum_{j in I_i} [nabla f_ij(x_new) - nabla f_ij(x_old)]
        with a SHARED sample multiset for both points (PAGE / line 8)."""
        idx = self._sample_idx(key, batch)
        gfun = jax.grad(self.loss)

        def node(a, y, ids):
            def per(j):
                return gfun(x_new, a[j], y[j]) - gfun(x_old, a[j], y[j])
            return jnp.mean(jax.vmap(per)(ids), 0)

        return jax.vmap(node)(self.features, self.labels, idx)


@dataclasses.dataclass(frozen=True)
class StochasticProblem:
    """f_i(x) = E_xi[loss(x, xi, i)]  (eq. (3)).

    ``sample``: (key, node_idx, batch) -> batch of xi realisations;
    ``loss``: per-sample stochastic loss.  Used for DASHA-MVR / SYNC-MVR /
    VR-MARINA(online).
    """

    loss: Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
    sample: Callable[[jax.Array, jax.Array, int], jax.Array]
    n: int
    # exact gradient of E[f] when available (synthetic problems), for metrics
    true_grad: Callable[[jax.Array], jax.Array] | None = None

    def stoch_grad(self, key: jax.Array, x: jax.Array,
                   batch: int) -> jax.Array:
        """(n, d): fresh minibatch stochastic gradient per node."""
        gfun = jax.grad(self.loss)

        def node(i, k):
            xi = self.sample(k, i, batch)
            return jnp.mean(jax.vmap(lambda s: gfun(x, s, i))(xi), 0)

        keys = jax.random.split(key, self.n)
        return jax.vmap(node)(jnp.arange(self.n), keys)

    def stoch_grad_pair(self, key: jax.Array, x_new: jax.Array,
                        x_old: jax.Array, batch: int
                        ) -> Tuple[jax.Array, jax.Array]:
        """Gradients at x_new and x_old with the SAME xi samples (MVR)."""
        gfun = jax.grad(self.loss)

        def node(i, k):
            xi = self.sample(k, i, batch)
            gn = jnp.mean(jax.vmap(lambda s: gfun(x_new, s, i))(xi), 0)
            go = jnp.mean(jax.vmap(lambda s: gfun(x_old, s, i))(xi), 0)
            return gn, go

        keys = jax.random.split(key, self.n)
        return jax.vmap(node)(jnp.arange(self.n), keys)
