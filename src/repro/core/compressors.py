"""DEPRECATED seed-era shim: the compressors live in :mod:`repro.compress`.

Kept so ``from repro.core.compressors import RandK`` (the seed's import
path) keeps working; all omega calculus, masking randomness and execution
route through the layered subsystem (spec / plan / backends — DESIGN.md
§3-§6).  Import from :mod:`repro.compress` (or construct a
:class:`repro.compress.RoundCompressor`) instead.
"""
import warnings

warnings.warn(
    "repro.core.compressors is a deprecated seed-era shim; import from "
    "repro.compress instead (see DESIGN.md §2).",
    DeprecationWarning, stacklevel=2)

from repro.compress.legacy import (Compressor, Identity,  # noqa: F401,E402
                                   PartialParticipation, PermK, QDither,
                                   RandK, empirical_omega, make_compressor)
from repro.compress.spec import CompressorSpec, make_spec  # noqa: F401,E402
