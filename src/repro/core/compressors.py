"""Back-compat shim: the compressors now live in :mod:`repro.compress`.

Kept so ``from repro.core.compressors import RandK`` (the seed's import
path, used throughout tests/benchmarks/examples) keeps working; all omega
calculus, masking randomness and execution now route through the layered
subsystem (spec / plan / backends — see DESIGN.md §3-§6).
"""
from repro.compress.legacy import (Compressor, Identity,  # noqa: F401
                                   PartialParticipation, PermK, QDither,
                                   RandK, empirical_omega, make_compressor)
from repro.compress.spec import CompressorSpec, make_spec  # noqa: F401
