"""Unbiased compressors (Definition 1.1) and their omega calculus.

A compressor here is a pure function ``compress(key, x) -> CompressedMsg`` plus
``decompress(msg) -> x_hat`` with ``E[x_hat] = x`` and
``E||x_hat - x||^2 <= omega * ||x||^2`` (class U(omega), eq. (4) of the paper).

All compressors operate on flat 1-D vectors; pytree plumbing lives in
:mod:`repro.core.pytree_util`.  ``expected_density`` implements Definition 1.3
(zeta_C), used by the communication-complexity accounting and benchmarks.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CompressedMsg:
    """A compressed message.

    ``dense`` is the decompressed d-vector (kept for math/aggregation on CPU and
    for the ``independent`` execution mode); ``payload_coords`` is the number of
    scalar coordinates a real wire transfer would carry (Definition 1.3 style
    accounting, used to plot 'bits sent per node').
    """

    dense: jax.Array
    payload_coords: int


class Compressor:
    """Base class: an element of U(omega)."""

    #: variance parameter omega such that C in U(omega)
    omega: float
    #: expected number of nonzero coords returned (zeta_C, Definition 1.3)
    expected_density: float

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        """Return the decompressed estimate C(x) (dense d-vector)."""
        raise NotImplementedError

    def payload(self, d: int) -> float:
        """Scalar coordinates sent over the wire per message of dimension d."""
        return self.expected_density


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    """No compression: C(x) = x, omega = 0 (sanity baseline; DASHA -> GD)."""

    d: int

    @property
    def omega(self) -> float:  # type: ignore[override]
        return 0.0

    @property
    def expected_density(self) -> float:  # type: ignore[override]
        return float(self.d)

    def __call__(self, key, x):
        return x


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """RandK sparsifier (Definition F.1): keep K uniformly random coords, scale
    by d/K.  C in U(d/K - 1) (Theorem F.2)."""

    d: int
    k: int

    @property
    def omega(self) -> float:  # type: ignore[override]
        return self.d / self.k - 1.0

    @property
    def expected_density(self) -> float:  # type: ignore[override]
        return float(self.k)

    def mask(self, key: jax.Array) -> jax.Array:
        """0/1 mask with exactly K ones (without replacement)."""
        # Top-k of iid uniforms == uniform K-subset without replacement.
        u = jax.random.uniform(key, (self.d,))
        thresh = jax.lax.top_k(u, self.k)[0][-1]
        return (u >= thresh).astype(jnp.float32)

    def __call__(self, key, x):
        m = self.mask(key).astype(x.dtype)
        return x * m * (self.d / self.k)


@dataclasses.dataclass(frozen=True)
class PermK(Compressor):
    """PermK (Szlendak, Tyurin & Richtarik 2021).

    The d coordinates are split into n equal blocks by a per-round random
    permutation; node ``node_idx`` sends exactly its block scaled by n.
    Unbiased with omega = n - 1 *as a collection*; on a TPU mesh the
    aggregation is exactly a reduce-scatter (+ all-gather), which is why this
    is our beyond-paper collective-optimal mode.  Requires d % n == 0 (the ops
    layer pads).
    """

    d: int
    n: int
    node_idx: int = 0

    @property
    def omega(self) -> float:  # type: ignore[override]
        return self.n - 1.0

    @property
    def expected_density(self) -> float:  # type: ignore[override]
        return self.d / self.n

    def mask(self, key: jax.Array) -> jax.Array:
        perm = jax.random.permutation(key, self.d)
        block = self.d // self.n
        sel = jax.lax.dynamic_slice(perm, (self.node_idx * block,), (block,))
        return jnp.zeros((self.d,), jnp.float32).at[sel].set(1.0)

    def __call__(self, key, x):
        return x * self.mask(key).astype(x.dtype) * self.n


@dataclasses.dataclass(frozen=True)
class QDither(Compressor):
    """Unbiased stochastic quantization (QSGD-style, s levels, per-vector L2
    scale).  omega <= min(d/s^2, sqrt(d)/s) (Alistarh et al. 2017, Lemma 3.1).

    Payload: d small ints + 1 float; we count it as d * (bits(s)/32) + 1
    equivalent fp32 coordinates.
    """

    d: int
    s: int = 15  # levels -> 4-bit payload

    @property
    def omega(self) -> float:  # type: ignore[override]
        return float(min(self.d / self.s**2, np.sqrt(self.d) / self.s))

    @property
    def expected_density(self) -> float:  # type: ignore[override]
        bits = np.ceil(np.log2(self.s + 1)) + 1  # levels + sign
        return float(self.d * bits / 32.0 + 1.0)

    def __call__(self, key, x):
        norm = jnp.linalg.norm(x)
        safe = jnp.where(norm > 0, norm, 1.0)
        y = jnp.abs(x) / safe * self.s  # in [0, s]
        lo = jnp.floor(y)
        prob = y - lo
        rnd = jax.random.uniform(key, x.shape, dtype=jnp.float32).astype(x.dtype)
        q = lo + (rnd < prob).astype(x.dtype)
        out = jnp.sign(x) * q * safe / self.s
        return jnp.where(norm > 0, out, jnp.zeros_like(x))


@dataclasses.dataclass(frozen=True)
class PartialParticipation(Compressor):
    """C_{p'} wrapper (Appendix D, Theorem D.1): with prob p' send C(x)/p',
    else send nothing.  If C in U(omega) then C_{p'} in U((omega+1)/p' - 1)."""

    base: Compressor
    p_participate: float

    @property
    def omega(self) -> float:  # type: ignore[override]
        return (self.base.omega + 1.0) / self.p_participate - 1.0

    @property
    def expected_density(self) -> float:  # type: ignore[override]
        return self.p_participate * self.base.expected_density

    def __call__(self, key, x):
        k_coin, k_base = jax.random.split(key)
        take = jax.random.bernoulli(k_coin, self.p_participate)
        return jnp.where(take, self.base(k_base, x) / self.p_participate,
                         jnp.zeros_like(x))


def make_compressor(name: str, d: int, *, k: Optional[int] = None,
                    n: int = 1, node_idx: int = 0, s: int = 15,
                    p_participate: float = 1.0) -> Compressor:
    """Factory used by configs / CLI."""
    name = name.lower()
    if name == "identity":
        base: Compressor = Identity(d)
    elif name == "randk":
        assert k is not None and 0 < k <= d
        base = RandK(d, k)
    elif name == "permk":
        base = PermK(d, n, node_idx)
    elif name == "qdither":
        base = QDither(d, s)
    else:
        raise ValueError(f"unknown compressor {name!r}")
    if p_participate < 1.0:
        return PartialParticipation(base, p_participate)
    return base


def empirical_omega(comp: Compressor, key: jax.Array, x: jax.Array,
                    trials: int = 512) -> float:
    """Monte-Carlo estimate of E||C(x)-x||^2 / ||x||^2 (test/diagnostic)."""
    keys = jax.random.split(key, trials)
    err = jax.vmap(lambda k: jnp.sum((comp(k, x) - x) ** 2))(keys)
    return float(jnp.mean(err) / jnp.sum(x**2))
