"""Back-compat object API over the spec/plan/backends layers.

These classes keep the seed's call signatures (``C(key, x) -> x_hat`` on
flat vectors, ``NodeCompressor(base, n, mode)`` on (n, d) stacks) while all
randomness and analytics now come from :mod:`repro.compress.plan` and
:mod:`repro.compress.spec`.  New code should use
:class:`repro.compress.RoundCompressor` directly; this module exists so the
paper-faithful reference loops and the existing tests/benchmarks keep
reading like the paper.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compress.backends import RoundCompressor
from repro.compress.plan import (indices_to_masks, perm_partition,
                                 randk_indices)
from repro.compress.spec import CompressorSpec, make_spec


class Compressor:
    """Base class: an element of U(omega) (Definition 1.1)."""

    #: variance parameter omega such that C in U(omega)
    omega: float
    #: expected number of nonzero coords returned (zeta_C, Definition 1.3)
    expected_density: float

    def as_spec(self, n: int = 1) -> CompressorSpec:
        """The registry spec this object is a view of."""
        raise NotImplementedError

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        """Return the decompressed estimate C(x) (dense d-vector)."""
        raise NotImplementedError

    def payload(self, d: int) -> float:
        """Scalar coordinates sent over the wire per message of dimension d."""
        return self.expected_density


def _spec_property(name):
    def get(self):
        return getattr(self.as_spec(getattr(self, "n", 1)), name)
    return property(get)


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    """No compression: C(x) = x, omega = 0 (sanity baseline; DASHA -> GD)."""

    d: int

    omega = _spec_property("omega")
    expected_density = _spec_property("expected_density")

    def as_spec(self, n: int = 1) -> CompressorSpec:
        return make_spec("identity", self.d)

    def __call__(self, key, x):
        return x


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """RandK sparsifier (Definition F.1): keep K uniformly random coords,
    scale by d/K.  C in U(d/K - 1) (Theorem F.2)."""

    d: int
    k: int

    omega = _spec_property("omega")
    expected_density = _spec_property("expected_density")

    def as_spec(self, n: int = 1) -> CompressorSpec:
        return make_spec("randk", self.d, k=self.k)

    def mask(self, key: jax.Array) -> jax.Array:
        """0/1 mask with exactly K ones (without replacement)."""
        return indices_to_masks(randk_indices(key, self.d, self.k)[None],
                                self.d)[0]

    def __call__(self, key, x):
        return x * self.mask(key).astype(x.dtype) * (self.d / self.k)


@dataclasses.dataclass(frozen=True)
class PermK(Compressor):
    """PermK (Szlendak, Tyurin & Richtarik 2021).

    The d coordinates are split into n equal blocks by a per-round random
    permutation; node ``node_idx`` sends exactly its block scaled by n.
    Unbiased with omega = n - 1 *as a collection*; on a TPU mesh the
    aggregation is exactly a reduce-scatter (+ all-gather), which is why
    this is our beyond-paper collective-optimal mode (DESIGN.md §3)."""

    d: int
    n: int
    node_idx: int = 0

    omega = _spec_property("omega")
    expected_density = _spec_property("expected_density")

    def as_spec(self, n: Optional[int] = None) -> CompressorSpec:
        # the collection size is this object's n; callers' n (e.g. the
        # PartialParticipation wrapper's default) must not override it
        return make_spec("permk", self.d, n=self.n)

    def mask(self, key: jax.Array) -> jax.Array:
        blocks = perm_partition(key, self.d, self.n)
        return indices_to_masks(blocks[self.node_idx][None], self.d)[0]

    def __call__(self, key, x):
        return x * self.mask(key).astype(x.dtype) * self.n


@dataclasses.dataclass(frozen=True)
class QDither(Compressor):
    """Unbiased stochastic quantization (QSGD-style, s levels, per-vector L2
    scale).  omega <= min(d/s^2, sqrt(d)/s) (Alistarh et al. 2017).

    Payload: d small ints + 1 float; counted as d * (bits(s)/32) + 1
    equivalent fp32 coordinates (see spec.py registration)."""

    d: int
    s: int = 15  # levels -> 4-bit payload

    omega = _spec_property("omega")
    expected_density = _spec_property("expected_density")

    def as_spec(self, n: int = 1) -> CompressorSpec:
        return make_spec("qdither", self.d, s=self.s)

    def __call__(self, key, x):
        from repro.kernels.ref import quantize_ref
        u = jax.random.uniform(key, x.shape, jnp.float32)
        return quantize_ref(x[None], u[None], self.s)[0]


@dataclasses.dataclass(frozen=True)
class PartialParticipation(Compressor):
    """C_{p'} wrapper (Appendix D, Theorem D.1): with prob p' send C(x)/p',
    else send nothing.  If C in U(omega) then C_{p'} in U((omega+1)/p' - 1)."""

    base: Compressor
    p_participate: float

    @property
    def omega(self) -> float:
        return self.as_spec().omega

    @property
    def expected_density(self) -> float:
        return self.as_spec().expected_density

    def as_spec(self, n: int = 1) -> CompressorSpec:
        return dataclasses.replace(self.base.as_spec(n),
                                   p_participate=self.p_participate)

    def __call__(self, key, x):
        k_coin, k_base = jax.random.split(key)
        take = jax.random.bernoulli(k_coin, self.p_participate)
        return jnp.where(take, self.base(k_base, x) / self.p_participate,
                         jnp.zeros_like(x))


def make_compressor(name: str, d: int, *, k: Optional[int] = None,
                    n: int = 1, node_idx: int = 0, s: int = 15,
                    p_participate: float = 1.0) -> Compressor:
    """Factory used by configs / CLI (registry-validated).

    .. deprecated:: use :func:`repro.compress.make_round_compressor`, which
       returns the spec/plan/backends front door directly."""
    warnings.warn(
        "make_compressor is deprecated; use "
        "repro.compress.make_round_compressor instead.",
        DeprecationWarning, stacklevel=2)
    name = name.lower()
    make_spec(name, d, k=k, n=n, s=s)      # validate against the registry
    if name == "identity":
        base: Compressor = Identity(d)
    elif name == "randk":
        base = RandK(d, k)
    elif name == "permk":
        base = PermK(d, n, node_idx)
    elif name == "qdither":
        base = QDither(d, s)
    else:
        raise ValueError(f"no legacy class for {name!r}; use "
                         "repro.compress.make_round_compressor")
    if p_participate < 1.0:
        return PartialParticipation(base, p_participate)
    return base


def empirical_omega(comp, key: jax.Array, x: jax.Array,
                    trials: int = 512) -> float:
    """Monte-Carlo estimate of E||C(x)-x||^2 / ||x||^2 (test/diagnostic)."""
    keys = jax.random.split(key, trials)
    err = jax.vmap(lambda k: jnp.sum((comp(k, x) - x) ** 2))(keys)
    return float(jnp.mean(err) / jnp.sum(x**2))


@dataclasses.dataclass(frozen=True)
class NodeCompressor:
    """Legacy (n, d) entry point; a thin view over RoundCompressor.

    Three execution modes (DESIGN.md §3): ``independent`` (paper-faithful
    Assumption 1.2, per-node randomness), ``shared_coords`` (one RandK index
    set shared by all nodes per round) and ``permk`` (disjoint partition of
    a shared per-round permutation).  ``backend`` additionally picks the
    execution strategy (§5): dense | sparse | fused.
    """

    base: Compressor
    n: int
    mode: str = "independent"  # independent | shared_coords | permk
    backend: str = "dense"     # dense | sparse | fused

    def __post_init__(self):
        warnings.warn(
            "NodeCompressor is a deprecated legacy view; construct "
            "repro.compress.RoundCompressor (make_round_compressor) "
            "directly.", DeprecationWarning, stacklevel=2)

    @property
    def rc(self) -> RoundCompressor:
        return RoundCompressor(self.base.as_spec(self.n), self.n,
                               self.mode, self.backend)

    @property
    def omega(self) -> float:
        return self.rc.omega

    @property
    def payload_per_node(self) -> float:
        return self.rc.payload_per_node

    def plan(self, key):
        return self.rc.plan(key)

    def compress(self, key, deltas):
        return self.rc.compress(key, deltas)

    def estimator_update(self, key, h_new, h, g_local, a):
        return self.rc.estimator_update(key, h_new, h, g_local, a)

    def __call__(self, key: jax.Array, deltas: jax.Array) -> jax.Array:
        """deltas: (n, d) -> messages m_i: (n, d) (dense representation)."""
        return self.rc(key, deltas)
