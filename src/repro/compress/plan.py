"""Layer 2 of the compression subsystem: per-round randomness ("plans").

Every compressor draws its randomness HERE, exactly once per round, through
one of four primitives:

* :func:`draw_mask`       — Bernoulli(p) 0/1 mask (u8-threshold fast path);
* :func:`randk_indices`   — uniform K-subset without replacement (RandK);
* :func:`perm_partition`  — a shared permutation split into n node blocks
                            (PermK, flat path);
* :func:`permk_owner`     — the cyclic-shift ownership map (PermK, pytree /
                            GSPMD path: iota only, no d-sized permutation).

The resulting :class:`Plan` is backend-agnostic: the dense, sparse and fused
execution backends (see :mod:`repro.compress.backends`) all consume the same
plan, which is what makes sparse-vs-dense messages bit-identical under the
same key and lets the fused Pallas kernels reuse the masks.  See DESIGN.md
§5 (execution backends) and §6 (payload accounting).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

#: sentinel index value used to pad ragged PermK blocks (>= d, dropped by
#: ``mode="drop"`` scatters and masked out of gathers)
PAD = jnp.iinfo(jnp.int32).max


class Plan(NamedTuple):
    """Per-round compression randomness, shared by every backend.

    ``kind`` selects the execution family:

    * ``"sparsify"``    — coordinate selection; ``indices`` (static-K
      compressors: RandK / PermK) and/or ``mask`` (Bernoulli) carry the
      support, ``scale`` the unbiasedness rescale.
    * ``"dither"``      — stochastic quantization; ``dither_u`` carries the
      external uniforms (so dense / fused paths quantize identically).
    * ``"passthrough"`` — identity.

    ``payload_coords`` counts fp32-equivalent scalars per node message under
    ideal entropy coding (Definition 1.3 accounting); ``wire_coords`` counts
    what the sparse wire format actually moves (values + indices).
    """

    kind: str
    scale: Union[float, jax.Array]
    indices: Optional[jax.Array] = None       # (n, k) int32, PAD-padded
    mask: Optional[jax.Array] = None          # (n, d) 0/1, or None
    dither_u: Optional[jax.Array] = None      # (n, d) uniforms
    levels: int = 0                           # dither levels s
    payload_coords: float = 0.0
    wire_coords: float = 0.0


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def draw_mask(k: jax.Array, shape, p: float) -> jax.Array:
    """Bernoulli(p) mask; u8-threshold path (exact when p is a multiple of
    1/256) avoids materialising u32 bits + f32 uniforms over d elements."""
    thresh256 = p * 256.0
    # p=1.0 must take the bernoulli path: uint8(256) would overflow
    if abs(thresh256 - round(thresh256)) < 1e-9 and 0 < round(thresh256) < 256:
        return jax.random.bits(k, shape, jnp.uint8) \
            < jnp.uint8(round(thresh256))
    return jax.random.bernoulli(k, p, shape)


def randk_indices(key: jax.Array, d: int, k: int) -> jax.Array:
    """Uniform K-subset of [d] without replacement, as (k,) int32 indices.

    Top-k of iid uniforms == uniform K-subset without replacement."""
    u = jax.random.uniform(key, (d,))
    return jax.lax.top_k(u, k)[1].astype(jnp.int32)


def perm_partition(key: jax.Array, d: int, n: int) -> jax.Array:
    """PermK partition of [d] into n node blocks: (n, ceil(d/n)) indices.

    The inverse view of :func:`permk_owner` (SAME shift draw, so the flat
    and pytree PermK paths agree bit-for-bit under one key): node i owns
    ``c = (i*blk + j - shift) mod n*blk`` for j in [0, blk).  O(d) iota
    arithmetic — no d-sized permutation/sort, which costs ~17 s at d=1e7 on
    CPU and is why the cyclic-shift partition is this repo's PermK
    everywhere (per-coordinate ownership marginals stay exactly 1/n, so
    unbiasedness and omega = n-1 are unchanged; beyond-paper adaptation,
    DESIGN.md §3).  When ``d % n != 0`` out-of-range slots carry the
    :data:`PAD` sentinel; backends drop / zero them."""
    blk = -(-d // n)                          # ceil
    shift = jax.random.randint(key, (), 0, n * blk)
    c = (jnp.arange(n * blk, dtype=jnp.int32).reshape(n, blk) - shift) \
        % (n * blk)
    return jnp.where(c < d, c, PAD)


def permk_owner(key: jax.Array, shape, n: int) -> jax.Array:
    """PermK ownership map for one leaf of shape ``shape`` (no node axis):
    coordinate c belongs to node ``owner(c) = ((c + shift) // blk) % n``.

    Iota + cyclic shift only — no (n, n, blk) intermediates, no rolls, no
    d-sized permutation — so GSPMD keeps every tensor at its own footprint
    (the roll formulation compiled to 5x peak memory; EXPERIMENTS.md §Perf).
    """
    L = 1
    for s in shape:
        L *= int(s)
    blk = -(-L // n)                          # ceil
    shift = jax.random.randint(key, (), 0, n * blk)
    owner = ((jnp.arange(L) + shift) // blk) % n
    return owner.reshape(shape)


def indices_to_masks(indices: jax.Array, d: int,
                     dtype=jnp.float32) -> jax.Array:
    """(n, k) PAD-padded indices -> (n, d) 0/1 masks (PAD slots dropped)."""
    def one(idx):
        return jnp.zeros((d,), dtype).at[idx].set(1.0, mode="drop")
    return jax.vmap(one)(indices)


def participation_coins(key: jax.Array, n: int, p: float) -> jax.Array:
    """Per-node Bernoulli(p) participation coins as a (n, 1) f32 factor of
    ``coin / p`` (Appendix D wrapper C_{p'}): multiply into any plan's scale
    or mask to get the partial-participation variant."""
    coins = jax.random.bernoulli(key, p, (n,))
    return (coins.astype(jnp.float32) / p)[:, None]
