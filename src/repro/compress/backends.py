"""Layer 3 of the compression subsystem: interchangeable execution backends.

Three ways to execute the SAME :class:`~repro.compress.plan.Plan` on a
stacked (n, d) message matrix:

* ``dense``  — reference semantics: messages are materialized d-vectors
  (mask-multiply).  What the math in the paper writes down.
* ``sparse`` — real wire format: a RandK/PermK message is carried as
  ``(indices, values)`` so aggregation touches K << d coordinates and the
  byte accounting stops being fictional.  Bit-identical values to ``dense``
  under the same key (same plan, same multiply ordering).
* ``fused``  — the Pallas kernel path (:mod:`repro.kernels.ops`): the whole
  estimator update (Alg. 1 lines 8-10) runs in one HBM pass, with the plan's
  mask applied in VMEM registers.

See DESIGN.md §5 for when each backend wins.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

from repro.compress.plan import Plan, indices_to_masks
from repro.compress.spec import (REGISTRY, CompressorSpec, make_plan,
                                 make_spec)

BACKENDS = ("dense", "sparse", "fused")


# ---------------------------------------------------------------------------
# message containers
# ---------------------------------------------------------------------------

class DenseMessages(NamedTuple):
    """n per-node messages, materialized as (n, d) dense rows."""

    values: jax.Array             # (n, d)
    payload_coords: float
    wire_coords: float

    @property
    def n(self) -> int:
        return self.values.shape[0]

    def dense(self) -> jax.Array:
        return self.values

    def mean(self) -> jax.Array:
        """Server aggregate (1/n) sum_i m_i, fp32."""
        return jnp.mean(self.values.astype(jnp.float32), 0)

    def add_to(self, g_local: jax.Array) -> jax.Array:
        """g_i <- g_i + m_i (Alg. 1 line 10)."""
        return g_local + self.values.astype(g_local.dtype)


class SparseMessages(NamedTuple):
    """n per-node messages in wire format: (indices, values) pairs.

    ``indices``: (n, k) int32, PAD-padded (out-of-range slots are dropped by
    every scatter and carry zero values).  Aggregation and the g_i update
    touch only the k kept coordinates per node.
    """

    indices: jax.Array            # (n, k) int32
    values: jax.Array             # (n, k)
    d: int
    payload_coords: float
    wire_coords: float

    @property
    def n(self) -> int:
        return self.values.shape[0]

    def dense(self) -> jax.Array:
        def one(idx, val):
            return jnp.zeros((self.d,), val.dtype).at[idx].add(val,
                                                               mode="drop")
        return jax.vmap(one)(self.indices, self.values)

    def mean(self) -> jax.Array:
        flat_i = self.indices.reshape(-1)
        flat_v = self.values.astype(jnp.float32).reshape(-1) / self.n
        return jnp.zeros((self.d,), jnp.float32).at[flat_i].add(flat_v,
                                                                mode="drop")

    def add_to(self, g_local: jax.Array) -> jax.Array:
        def one(g, idx, val):
            return g.at[idx].add(val.astype(g.dtype), mode="drop")
        return jax.vmap(one)(g_local, self.indices, self.values)


Messages = Union[DenseMessages, SparseMessages]


# ---------------------------------------------------------------------------
# backend execution
# ---------------------------------------------------------------------------

def _dense_values(plan: Plan, deltas: jax.Array) -> jax.Array:
    """(n, d) messages with reference (dense-multiply) semantics."""
    if plan.kind == "passthrough":
        return deltas * plan.scale
    if plan.kind == "dither":
        from repro.kernels.ref import quantize_ref
        return quantize_ref(deltas, plan.dither_u, plan.levels) * plan.scale
    mask = plan.mask
    if mask is None:
        mask = indices_to_masks(plan.indices, deltas.shape[-1],
                                dtype=deltas.dtype)
    return deltas * mask.astype(deltas.dtype) * plan.scale


def apply_dense(plan: Plan, deltas: jax.Array) -> DenseMessages:
    return DenseMessages(values=_dense_values(plan, deltas),
                         payload_coords=plan.payload_coords,
                         wire_coords=float(deltas.shape[-1]))


def apply_sparse(plan: Plan, deltas: jax.Array) -> Messages:
    """Wire-format execution.  Static-K compressors (RandK/PermK) gather the
    kept coordinates; mask/dither compressors have no static support so they
    fall back to dense values while keeping honest wire accounting."""
    if plan.indices is None:
        msgs = apply_dense(plan, deltas)
        return msgs._replace(wire_coords=plan.wire_coords)
    d = deltas.shape[-1]

    def gather(x, idx):
        valid = (idx < d).astype(x.dtype)
        return x[jnp.minimum(idx, d - 1)] * valid

    vals = jax.vmap(gather)(deltas, plan.indices) * plan.scale
    return SparseMessages(indices=plan.indices, values=vals, d=d,
                          payload_coords=plan.payload_coords,
                          wire_coords=plan.wire_coords)


def fused_estimator_update(plan: Plan, h_new: jax.Array, h: jax.Array,
                           g_local: jax.Array, a: float
                           ) -> Tuple[Messages, jax.Array, jax.Array]:
    """Alg. 1 lines 9-10 through the fused Pallas kernel, one HBM pass:
    m = C(h_new - h - a (g_local - h)); g_i <- g_i + m_i.

    Returns (messages, h_out, g_local_new)."""
    from repro.kernels import ops as kops

    d = float(h_new.shape[-1])            # fused messages stay dense
    if plan.kind == "dither":
        delta = h_new - h - a * (g_local - h)
        m = kops.quantize_with_u(delta, plan.dither_u,
                                 plan.levels) * plan.scale
        return (DenseMessages(m, plan.payload_coords, d),
                h_new, g_local + m)

    if plan.kind == "passthrough":
        mask = jnp.ones(h_new.shape, jnp.float32)
    elif plan.mask is not None:
        mask = plan.mask.astype(jnp.float32)
    else:
        mask = indices_to_masks(plan.indices, h_new.shape[-1])
    if isinstance(plan.scale, jax.Array):
        # participation coins make the scale per-node: fold into the mask so
        # the kernel's scale stays a static scalar
        mask = mask * plan.scale.astype(jnp.float32)
        kscale = 1.0
    else:
        kscale = float(plan.scale)
    m, h_out, gl_new = kops.dasha_update(h_new, h, g_local, mask, a, kscale)
    return (DenseMessages(m, plan.payload_coords, d), h_out, gl_new)


# ---------------------------------------------------------------------------
# the front door
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundCompressor:
    """A per-round node-collection compressor: spec x mode x backend.

    This is the object the DASHA loops hold.  ``mode`` picks how the n
    nodes' randomness is coupled (DESIGN.md §3); ``backend`` picks the
    execution strategy (§5).  All combinations share the plan layer, so
    switching backend never changes the math.
    """

    spec: CompressorSpec
    n: int
    mode: str = "independent"
    backend: str = "dense"

    def __post_init__(self):
        defn = REGISTRY[self.spec.name]
        if self.mode not in defn.modes:
            raise ValueError(f"{self.spec.name} does not support mode "
                             f"{self.mode!r} (has {defn.modes})")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def omega(self) -> float:
        return self.spec.omega

    @property
    def payload_per_node(self) -> float:
        """Ideal-coding scalar coords per node message (Definition 1.3)."""
        return self.spec.expected_density

    @property
    def wire_per_node(self) -> float:
        """Coords the selected backend actually moves per node message."""
        if self.backend == "sparse":
            return self.spec.wire_coords(self.mode)
        return float(self.spec.d)

    def plan(self, key: jax.Array) -> Plan:
        return make_plan(self.spec, key, self.n, self.mode)

    def compress(self, key: jax.Array, deltas: jax.Array) -> Messages:
        """deltas: (n, d) -> per-node messages in this backend's format."""
        plan = self.plan(key)
        if self.backend == "sparse":
            return apply_sparse(plan, deltas)
        return apply_dense(plan, deltas)

    def __call__(self, key: jax.Array, deltas: jax.Array) -> jax.Array:
        """Legacy dense entry point: (n, d) -> (n, d) messages."""
        return self.compress(key, deltas).dense()

    def estimator_update(self, key: jax.Array, h_new: jax.Array,
                         h: jax.Array, g_local: jax.Array, a: float
                         ) -> Tuple[Messages, jax.Array, jax.Array]:
        """One-call Alg. 1 lines 9-10: compress the drift and update g_i.

        Returns (messages, h_out, g_local_new); ``h_out`` is ``h_new``
        passed through (the fused kernel writes it in the same pass)."""
        return estimator_update_with_plan(self.backend, self.plan(key),
                                          h_new, h, g_local, a)


def estimator_update_with_plan(backend: str, plan: Plan, h_new: jax.Array,
                               h: jax.Array, g_local: jax.Array, a: float
                               ) -> Tuple[Messages, jax.Array, jax.Array]:
    """:meth:`RoundCompressor.estimator_update` with an externally supplied
    (possibly transformed) plan — the hook the sampled-client substrate uses
    to fold the cohort inflation n/C into the plan's scale before execution
    (mirroring how Appendix-D coins fold into it in ``_wrap_participation``).
    """
    if backend == "fused":
        return fused_estimator_update(plan, h_new, h, g_local, a)
    delta = h_new - h - a * (g_local - h)
    if backend == "sparse":
        msgs = apply_sparse(plan, delta)
    else:
        msgs = apply_dense(plan, delta)
    return msgs, h_new, msgs.add_to(g_local)


def make_round_compressor(name: str, d: int, n: int, *,
                          mode: str = "independent",
                          backend: str = "dense", **kw) -> RoundCompressor:
    """Factory: registry name -> ready-to-use RoundCompressor."""
    if name.lower() == "permk":
        kw.setdefault("n", n)
    return RoundCompressor(make_spec(name, d, **kw), n, mode, backend)
