"""Pytree adapter for the compression subsystem (DESIGN.md §3-§5).

The flat layers (:mod:`plan` / :mod:`backends`) think in (n, d) matrices;
model training thinks in parameter pytrees whose leaves carry a leading node
axis and GSPMD shardings.  This module is the ONE place that bridges them:

* :func:`leaf_keys`          — per-leaf RNG key fanout;
* :func:`bernoulli_compress` — tree-level independent / shared_coords modes;
* :func:`permk_compress`     — tree-level PermK with exact aggregate;
* :func:`fused_tree_update`  — the Pallas fused path, now covering ALL modes
  (independent | shared_coords | permk) x variants (dasha | mvr), which lets
  :mod:`repro.optim.distributed` drop its old "kernel only if not permk and
  not mvr" restriction.

All masks come from :mod:`repro.compress.plan`, so the dense and fused paths
are parity-testable under the same key.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compress.plan import draw_mask, permk_owner

PyTree = Any


def leaf_keys(key: jax.Array, tree: PyTree) -> PyTree:
    """Split one round key into one key per leaf (same treedef)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = list(jax.random.split(key, len(leaves)))
    return jax.tree_util.tree_unflatten(treedef, keys)


def _spec_leaf(t) -> bool:
    from jax.sharding import PartitionSpec
    return t is None or isinstance(t, (jax.Array, PartitionSpec))


def _is_pair(t) -> bool:
    return isinstance(t, tuple) and len(t) == 2


def _is_triple(t) -> bool:
    return isinstance(t, tuple) and len(t) == 3


def _none_specs(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: None, tree)


# ---------------------------------------------------------------------------
# dense tree-level execution
# ---------------------------------------------------------------------------

def bernoulli_compress(key: jax.Array, delta: PyTree, p: float,
                       specs: Optional[PyTree] = None,
                       shared: bool = False) -> PyTree:
    """delta leaves: (n, *shape). Independent mask per node per coordinate;
    ``shared=True`` draws ONE mask per leaf shared by all nodes (the
    aggregate is then supported on ~p*d coords with a common index set —
    the `shared_coords` execution mode; loses the omega/n variance
    averaging across nodes, see DESIGN.md §3).

    ``specs``: optional PartitionSpecs (WITH the node axis) pinned onto the
    Bernoulli masks — forces the partitionable threefry RNG to generate its
    bits sharded instead of materialising an unsharded d-size mask."""
    def leaf(k, x, spec):
        shp = x.shape[1:] if shared else x.shape
        mask = draw_mask(k, shp, p)
        if shared:
            mask = jnp.broadcast_to(mask[None], x.shape)
        if spec is not None:
            mask = jax.lax.with_sharding_constraint(mask, spec)
        return jnp.where(mask, x / p, 0.0).astype(x.dtype)

    if specs is None:
        specs = _none_specs(delta)
    return jax.tree_util.tree_map(leaf, leaf_keys(key, delta), delta, specs,
                                  is_leaf=_spec_leaf)


def permk_compress(key: jax.Array, delta: PyTree, n: int,
                   specs: Optional[PyTree] = None) -> Tuple[PyTree, PyTree]:
    """Returns (messages m_i (n,*shape), exact aggregate mean_i m_i (*shape)).

    PermK partitioning via the shared cyclically-shifted ownership map
    (:func:`repro.compress.plan.permk_owner`) — iota masks only, no
    (n, n, blk) intermediates, no rolls — so GSPMD keeps every tensor at the
    (n, d) footprint (the roll formulation compiled to 5x peak memory; see
    EXPERIMENTS.md §Perf)."""
    from jax.sharding import PartitionSpec

    def leaf(k, x, spec):
        nloc = x.shape[0]
        owner = permk_owner(k, x.shape[1:], nloc)
        if spec is not None:              # shard the ownership iota too
            owner = jax.lax.with_sharding_constraint(
                owner, PartitionSpec(*tuple(spec)[1:]))
        ids = jnp.arange(nloc).reshape((nloc,) + (1,) * (x.ndim - 1))
        m = x * (owner[None] == ids).astype(x.dtype) * nloc
        if spec is not None:
            m = jax.lax.with_sharding_constraint(m, spec)
        # disjoint supports => the mean recovers exactly node owner(c)'s
        # value at c; computed as a plain mean so GSPMD emits ONE reduce
        # over the node axis.
        return m, jnp.mean(m.astype(jnp.float32), 0)

    if specs is None:
        specs = _none_specs(delta)
    pairs = jax.tree_util.tree_map(leaf, leaf_keys(key, delta), delta, specs,
                                   is_leaf=_spec_leaf)
    m = jax.tree_util.tree_map(lambda p_: p_[0], pairs, is_leaf=_is_pair)
    agg = jax.tree_util.tree_map(lambda p_: p_[1], pairs, is_leaf=_is_pair)
    return m, agg


# ---------------------------------------------------------------------------
# fused (Pallas) tree-level execution — full mode x variant coverage
# ---------------------------------------------------------------------------

def tree_masks(key: jax.Array, tree: PyTree, *, mode: str, p: float, n: int,
               specs: Optional[PyTree] = None) -> Tuple[PyTree, float]:
    """One (n, *shape) f32 {0,1} mask per leaf + the unbiasedness scale.

    Draws the SAME randomness as the dense paths above (same per-leaf key
    fanout, same primitives), so fused-vs-dense trajectories are
    parity-testable under a shared round key."""
    def leaf(k, x, spec):
        if mode == "permk":
            nloc = x.shape[0]
            # the returned scale is the tree-wide n: a leaf whose node axis
            # disagrees would get silently mis-scaled (biased estimator)
            assert nloc == n, (f"permk leaf node axis {nloc} != n={n}; "
                               "masks and scale would disagree")
            owner = permk_owner(k, x.shape[1:], nloc)
            ids = jnp.arange(nloc).reshape((nloc,) + (1,) * (x.ndim - 1))
            mask = (owner[None] == ids).astype(jnp.float32)
        elif mode == "shared_coords":
            mask = jnp.broadcast_to(draw_mask(k, x.shape[1:], p)[None],
                                    x.shape).astype(jnp.float32)
        else:
            mask = draw_mask(k, x.shape, p).astype(jnp.float32)
        if spec is not None:
            mask = jax.lax.with_sharding_constraint(mask, spec)
        return mask

    if specs is None:
        specs = _none_specs(tree)
    masks = jax.tree_util.tree_map(leaf, leaf_keys(key, tree), tree, specs,
                                   is_leaf=_spec_leaf)
    scale = float(n) if mode == "permk" else 1.0 / p
    return masks, scale


def fused_tree_update(key: jax.Array, grads_new: PyTree, h: PyTree,
                      g_local: PyTree, *, mode: str, a: float, p: float,
                      n: int, variant: str = "dasha", b: float = 0.0,
                      grads_old: Optional[PyTree] = None,
                      specs: Optional[PyTree] = None
                      ) -> Tuple[PyTree, PyTree, PyTree]:
    """Alg. 1 lines 8-10 per leaf in ONE Pallas HBM pass, for every mode.

    ``variant="dasha"``: h_new = grads_new.  ``variant="mvr"``: the kernel
    fuses the momentum h-update h_new = gn + (1-b)(h - go) as well
    (``grads_old`` required).  Returns (m, h_new, g_local_new) trees."""
    from repro.kernels import ops as kops

    masks, scale = tree_masks(key, grads_new, mode=mode, p=p, n=n,
                              specs=specs)

    if variant == "mvr":
        assert grads_old is not None, "mvr fused path needs grads_old"

        def leaf(mask, gn, go, hh, gl):
            return kops.dasha_mvr_update(gn, go, hh, gl, mask, a, b, scale)

        trips = jax.tree_util.tree_map(leaf, masks, grads_new, grads_old,
                                       h, g_local)
    else:
        def leaf(mask, gn, hh, gl):
            return kops.dasha_update(gn, hh, gl, mask, a, scale)

        trips = jax.tree_util.tree_map(leaf, masks, grads_new, h, g_local)

    def pick(i):
        return jax.tree_util.tree_map(lambda t: t[i], trips,
                                      is_leaf=_is_triple)

    return pick(0), pick(1), pick(2)
