"""Unified compression subsystem (DESIGN.md §3-§6).

Layering:

* :mod:`repro.compress.spec`      — CompressorSpec, omega calculus, registry;
* :mod:`repro.compress.plan`      — per-round masks / index sets / dither
  randomness, drawn once and shared by every backend;
* :mod:`repro.compress.backends`  — dense | sparse | fused execution on
  stacked (n, d) messages; RoundCompressor front door;
* :mod:`repro.compress.treelevel` — pytree adapter for model training
  (bernoulli_compress / permk_compress / fused_tree_update);
* :mod:`repro.compress.legacy`    — seed-compatible object API
  (Identity/RandK/PermK/QDither, make_compressor, NodeCompressor).
"""
from repro.compress.backends import (BACKENDS, DenseMessages,  # noqa: F401
                                     Messages, RoundCompressor,
                                     SparseMessages, apply_dense,
                                     apply_sparse, fused_estimator_update,
                                     make_round_compressor)
from repro.compress.legacy import (Compressor, Identity,  # noqa: F401
                                   NodeCompressor, PartialParticipation,
                                   PermK, QDither, RandK, empirical_omega,
                                   make_compressor)
from repro.compress.plan import (PAD, Plan, draw_mask,  # noqa: F401
                                 indices_to_masks, participation_coins,
                                 perm_partition, permk_owner, randk_indices)
from repro.compress.spec import (MODES, REGISTRY, CompressorDef,  # noqa: F401
                                 CompressorSpec, make_plan, make_spec,
                                 momentum_a, omega_bernoulli,
                                 omega_participation, omega_permk,
                                 register)
from repro.compress.treelevel import (bernoulli_compress,  # noqa: F401
                                      fused_tree_update, leaf_keys,
                                      permk_compress, tree_masks)


def as_round_compressor(comp) -> RoundCompressor:
    """Accept either a RoundCompressor or a legacy NodeCompressor."""
    if isinstance(comp, RoundCompressor):
        return comp
    return comp.rc
