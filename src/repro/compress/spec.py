"""Layer 1 of the compression subsystem: specs, omega calculus, registry.

A :class:`CompressorSpec` is pure metadata — name + sizes — from which the
registry computes everything analytic: the variance parameter omega of the
class U(omega) (Definition 1.1, eq. (4)), the expected density zeta_C
(Definition 1.3), and the two payload numbers used for communication
accounting (DESIGN.md §6).

Adding a compressor is ONE :func:`register` call: an omega formula, a
density formula, and a plan function built from the primitives in
:mod:`repro.compress.plan`.  All three execution backends (dense / sparse /
fused), the flat DASHA loop, the pytree trainer and the benchmarks pick the
new compressor up from the registry — nothing else to edit.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.plan import (Plan, draw_mask,
                                 participation_coins, perm_partition,
                                 randk_indices)

MODES = ("independent", "shared_coords", "permk")


@dataclasses.dataclass(frozen=True)
class CompressorSpec:
    """What to compress with; all analytics derive from the registry."""

    name: str
    d: int                        # message dimension
    k: Optional[int] = None       # randk: kept coords
    n: int = 1                    # permk: collection size
    s: int = 15                   # qdither: quantization levels
    p: float = 1.0                # bernoulli: keep probability
    p_participate: float = 1.0    # Appendix D partial-participation wrapper

    @property
    def omega(self) -> float:
        """Variance parameter: C in U(omega).  Wrapped for partial
        participation per Theorem D.1: (omega+1)/p' - 1."""
        base = REGISTRY[self.name].omega(self)
        if self.p_participate < 1.0:
            return omega_participation(base, self.p_participate)
        return base

    @property
    def expected_density(self) -> float:
        """zeta_C: expected nonzero (or fp32-equivalent) coords per message."""
        dens = REGISTRY[self.name].expected_density(self)
        return self.p_participate * dens

    @property
    def payload_coords(self) -> float:
        """Ideal-wire scalars per message (values only; index sets that are
        derivable from the shared round seed cost nothing)."""
        return self.expected_density

    def wire_coords(self, mode: str = "independent") -> float:
        """Scalars the *sparse wire format* actually moves per node message:
        values, plus the support description when the receiver cannot
        rederive it (independent RandK ships its private index set;
        shared_coords / shared-permk supports follow from the shared round
        seed so only values ship)."""
        return self.p_participate * REGISTRY[self.name].wire_coords(self,
                                                                    mode)

    def wire_bits(self, mode: str = "independent") -> float:
        """fp32 bits the sparse wire format moves (NOT Definition 1.3 —
        that is ``32 * payload_coords``; see DESIGN.md §6 for the split)."""
        return 32.0 * self.wire_coords(mode)


@dataclasses.dataclass(frozen=True)
class CompressorDef:
    """Registry entry: the full analytic + randomness definition."""

    name: str
    omega: Callable[[CompressorSpec], float]
    expected_density: Callable[[CompressorSpec], float]
    #: (spec, key, n_nodes, mode) -> Plan, built from plan.py primitives
    make_plan: Callable[[CompressorSpec, jax.Array, int, str], Plan]
    wire_coords: Callable[[CompressorSpec, str], float]
    modes: Tuple[str, ...] = MODES
    supports_sparse: bool = False


REGISTRY: Dict[str, CompressorDef] = {}


def register(defn: CompressorDef) -> CompressorDef:
    REGISTRY[defn.name] = defn
    return defn


def make_spec(name: str, d: int, *, k: Optional[int] = None, n: int = 1,
              s: int = 15, p: float = 1.0,
              p_participate: float = 1.0) -> CompressorSpec:
    name = name.lower()
    if name not in REGISTRY:
        raise ValueError(f"unknown compressor {name!r}; "
                         f"registered: {sorted(REGISTRY)}")
    if name == "randk":
        assert k is not None and 0 < k <= d, (k, d)
    return CompressorSpec(name=name, d=d, k=k, n=n, s=s, p=p,
                          p_participate=p_participate)


def _wrap_participation(plan: Plan, spec: CompressorSpec, key: jax.Array,
                        n: int) -> Plan:
    """Fold Appendix D coins into the plan's per-node scale."""
    if spec.p_participate >= 1.0:
        return plan
    factor = participation_coins(key, n, spec.p_participate)
    return plan._replace(scale=plan.scale * factor,
                         payload_coords=plan.payload_coords
                         * spec.p_participate,
                         wire_coords=plan.wire_coords * spec.p_participate)


def make_plan(spec: CompressorSpec, key: jax.Array, n: int,
              mode: str = "independent") -> Plan:
    """Draw ALL of this round's compression randomness, for n nodes."""
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}")
    k_plan, k_pp = jax.random.split(key)
    plan = REGISTRY[spec.name].make_plan(spec, k_plan, n, mode)
    return _wrap_participation(plan, spec, k_pp, n)


# ---------------------------------------------------------------------------
# registrations — each is one compact block; this is the whole cost of a
# new compressor (DESIGN.md §5 walks through adding one)
# ---------------------------------------------------------------------------

def _identity_plan(spec, key, n, mode):
    return Plan(kind="passthrough", scale=1.0,
                payload_coords=float(spec.d), wire_coords=float(spec.d))


register(CompressorDef(
    name="identity",
    omega=lambda s: 0.0,
    expected_density=lambda s: float(s.d),
    make_plan=_identity_plan,
    wire_coords=lambda s, m: float(s.d),
))


def _randk_plan(spec, key, n, mode):
    d, k = spec.d, spec.k
    if mode == "shared_coords":
        idx = jnp.broadcast_to(randk_indices(key, d, k)[None], (n, k))
        wire = float(k)                       # support rederivable from seed
    else:
        idx = jax.vmap(lambda kk: randk_indices(kk, d, k))(
            jax.random.split(key, n))
        wire = 2.0 * k                        # private support: idx + values
    return Plan(kind="sparsify", scale=float(d) / k, indices=idx,
                payload_coords=float(k), wire_coords=wire)


register(CompressorDef(
    name="randk",
    omega=lambda s: s.d / s.k - 1.0,          # Theorem F.2
    expected_density=lambda s: float(s.k),
    make_plan=_randk_plan,
    wire_coords=lambda s, m: (float(s.k) if m == "shared_coords"
                              else 2.0 * s.k),
    modes=("independent", "shared_coords"),
    supports_sparse=True,
))


def _permk_plan(spec, key, n, mode):
    if mode == "independent":
        # paper-faithful Assumption 1.2: node i draws its OWN partition and
        # keeps block i of it (private random block; supports may overlap
        # across nodes).  Support is described by one private shift scalar.
        idx = jax.vmap(lambda i, kk: perm_partition(kk, spec.d, n)[i])(
            jnp.arange(n), jax.random.split(key, n))
        wire = float(idx.shape[1]) + 1.0      # values + the shift
    else:
        idx = perm_partition(key, spec.d, n)  # shared: (n, ceil(d/n))
        wire = float(idx.shape[1])            # shift follows the round seed
    return Plan(kind="sparsify", scale=float(n), indices=idx,
                payload_coords=spec.d / n, wire_coords=wire)


register(CompressorDef(
    name="permk",
    omega=lambda s: s.n - 1.0,                # as a collection (Szlendak+21)
    expected_density=lambda s: s.d / s.n,
    make_plan=_permk_plan,
    wire_coords=lambda s, m: (float(-(-s.d // s.n))
                              + (1.0 if m == "independent" else 0.0)),
    modes=("independent", "permk"),
    supports_sparse=True,
))


def _bernoulli_wire(spec, mode) -> float:
    # shared_coords: the mask follows from the shared round seed, only
    # values ship; independent: the private support ships as indices too.
    factor = 1.0 if mode == "shared_coords" else 2.0
    return factor * spec.p * spec.d


def _bernoulli_plan(spec, key, n, mode):
    d, p = spec.d, spec.p
    if mode == "shared_coords":
        mask = jnp.broadcast_to(draw_mask(key, (d,), p)[None], (n, d))
    else:
        mask = draw_mask(key, (n, d), p)
    mask = mask.astype(jnp.float32)
    return Plan(kind="sparsify", scale=1.0 / p, mask=mask,
                payload_coords=p * d,
                wire_coords=_bernoulli_wire(spec, mode))


register(CompressorDef(
    name="bernoulli",
    omega=lambda s: 1.0 / s.p - 1.0,          # RandP sparsifier
    expected_density=lambda s: s.p * s.d,
    make_plan=_bernoulli_plan,
    wire_coords=_bernoulli_wire,
    modes=("independent", "shared_coords"),
))


def _qdither_payload(spec) -> float:
    bits = np.ceil(np.log2(spec.s + 1)) + 1   # levels + sign
    return float(spec.d * bits / 32.0 + 1.0)  # + the fp32 norm


def _qdither_plan(spec, key, n, mode):
    u = jax.random.uniform(key, (n, spec.d), jnp.float32)
    pay = _qdither_payload(spec)
    return Plan(kind="dither", scale=1.0, dither_u=u, levels=spec.s,
                payload_coords=pay, wire_coords=pay)


register(CompressorDef(
    name="qdither",
    # omega <= min(d/s^2, sqrt(d)/s)  (Alistarh et al. 2017, Lemma 3.1)
    omega=lambda s: float(min(s.d / s.s**2, np.sqrt(s.d) / s.s)),
    expected_density=_qdither_payload,
    make_plan=_qdither_plan,
    wire_coords=lambda s, m: _qdither_payload(s),
    modes=("independent",),
))


# -- omega calculus used by configs that know p/n before d ------------------

def omega_bernoulli(p: float) -> float:
    """Bernoulli-RandP: omega = 1/p - 1 (DashaTrainConfig's compression)."""
    return 1.0 / p - 1.0


def omega_permk(n: int) -> float:
    """PermK collection: omega = n - 1."""
    return float(n - 1)


def momentum_a(omega: float) -> float:
    """The compressor momentum a = 1/(2 omega + 1) (Theorem 6.1)."""
    return 1.0 / (2.0 * omega + 1.0)


def omega_participation(omega: float, p: float) -> float:
    """Theorem D.1: wrapping a U(omega) compressor in a probability-p
    participation (or uniform C-of-n cohort sampling, p = C/n) layer yields
    a U((omega+1)/p - 1) compressor — the same DASHA theory applies with
    the inflated omega."""
    return (omega + 1.0) / p - 1.0
