"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: tests sweep shapes/dtypes and assert
``assert_allclose(kernel(interpret=True), ref)``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def dasha_update_ref(grad: jax.Array, h: jax.Array, g_local: jax.Array,
                     mask: jax.Array, a: float, scale: float
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused DASHA node update (Alg. 1 lines 8-10, GD-like h), elementwise:

        h_new   = grad
        delta   = h_new - h - a * (g_local - h)
        m       = mask * delta * scale          (unbiased sparsifier)
        g_new   = g_local + m

    Returns (m, h_new, g_new); every tensor float32, shape of ``grad``.
    """
    h_new = grad
    delta = h_new - h - a * (g_local - h)
    m = mask * delta * scale
    return m, h_new, g_local + m


def dasha_mvr_update_ref(grad_new: jax.Array, grad_old: jax.Array,
                         h: jax.Array, g_local: jax.Array, mask: jax.Array,
                         a: float, b: float, scale: float
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused DASHA-MVR node update (Alg. 1 line 8 MVR + lines 9-10):

        h_new = grad_new + (1-b) * (h - grad_old)
        delta = h_new - h - a * (g_local - h)
        m     = mask * delta * scale
        g_new = g_local + m
    """
    h_new = grad_new + (1.0 - b) * (h - grad_old)
    delta = h_new - h - a * (g_local - h)
    m = mask * delta * scale
    return m, h_new, g_local + m


def quantize_ref(x: jax.Array, u: jax.Array, levels: int) -> jax.Array:
    """Per-row unbiased stochastic quantization (QSGD, s=levels):

        y = |x| / ||x||_2 * s;  q = floor(y) + Bernoulli(y - floor(y))
        out = sign(x) * q * ||x||_2 / s

    ``x``: (R, C); ``u``: uniform(0,1) of the same shape (external RNG);
    row-wise L2 scale.  Zero rows pass through as zeros.
    """
    xf = x.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(xf * xf, axis=-1, keepdims=True))
    safe = jnp.where(norm > 0, norm, 1.0)
    y = jnp.abs(xf) / safe * levels
    lo = jnp.floor(y)
    q = lo + (u < (y - lo)).astype(jnp.float32)
    out = jnp.sign(xf) * q * safe / levels
    return jnp.where(norm > 0, out, 0.0).astype(x.dtype)
