"""Pallas TPU kernel: fused DASHA node update.

Why a kernel: DASHA's per-round node work (Alg. 1 lines 8-10) is *pure
streaming* over the d-dimensional parameter space (d ~ 1e7-1e11 in the
paper's DNN experiment and our assigned architectures).  Written naively it
is 4-6 separate elementwise HLO ops = 4-6 round trips through HBM for
tensors that are each ~4d bytes.  The fused kernel makes exactly ONE pass:
read (grad, h, g_local, mask), write (m, h_new, g_local_new) — turning an
optimizer step that is ~6x memory-bound into the minimal 4-read/3-write
stream.  This is the TPU adaptation of the paper's "send compressed vectors
only" insight: compression (masking+scaling) happens in VMEM registers while
the state tensors stream through, so the compressed message m is produced
for free on top of the mandatory estimator update traffic.

Tiling: inputs are reshaped to (R, 128) by the ops layer; the grid walks R in
blocks of ``block_rows`` rows so each program holds
``7 tensors x block_rows x 128 x 4B`` in VMEM (block_rows=2048 -> ~7 MB,
comfortably under the ~16 MB v5e VMEM budget while keeping the last dim at
the 128-lane width).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # TPU vector lane width: last dim of every block
DEFAULT_BLOCK_ROWS = 2048


def _dasha_update_kernel(a_ref, scale_ref, grad_ref, h_ref, gl_ref, mask_ref,
                         m_ref, h_out_ref, gl_out_ref):
    a = a_ref[0]
    scale = scale_ref[0]
    grad = grad_ref[...]
    h = h_ref[...]
    gl = gl_ref[...]
    delta = grad - h - a * (gl - h)
    m = mask_ref[...] * delta * scale
    m_ref[...] = m
    h_out_ref[...] = grad
    gl_out_ref[...] = gl + m


def _dasha_mvr_update_kernel(a_ref, b_ref, scale_ref, gn_ref, go_ref, h_ref,
                             gl_ref, mask_ref, m_ref, h_out_ref, gl_out_ref):
    a = a_ref[0]
    b = b_ref[0]
    scale = scale_ref[0]
    h = h_ref[...]
    gl = gl_ref[...]
    h_new = gn_ref[...] + (1.0 - b) * (h - go_ref[...])
    delta = h_new - h - a * (gl - h)
    m = mask_ref[...] * delta * scale
    m_ref[...] = m
    h_out_ref[...] = h_new
    gl_out_ref[...] = gl + m


def _grid_specs(rows: int, block_rows: int, n_scalars: int, n_tensors: int):
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    tens = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    scal = pl.BlockSpec((1,), lambda i: (0,))
    return grid, [scal] * n_scalars + [tens] * n_tensors, [tens] * 3


def dasha_update_pallas(grad: jax.Array, h: jax.Array, g_local: jax.Array,
                        mask: jax.Array, a: float, scale: float, *,
                        block_rows: int = DEFAULT_BLOCK_ROWS,
                        interpret: bool = True
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """All array args: (R, 128) float32.  Returns (m, h_new, g_local_new)."""
    rows = grad.shape[0]
    grid, in_specs, out_specs = _grid_specs(rows, block_rows, 2, 4)
    shape = jax.ShapeDtypeStruct(grad.shape, grad.dtype)
    return pl.pallas_call(
        _dasha_update_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=(shape, shape, shape),
        interpret=interpret,
    )(jnp.full((1,), a, grad.dtype), jnp.full((1,), scale, grad.dtype),
      grad, h, g_local, mask)


def dasha_mvr_update_pallas(grad_new: jax.Array, grad_old: jax.Array,
                            h: jax.Array, g_local: jax.Array,
                            mask: jax.Array, a: float, b: float,
                            scale: float, *,
                            block_rows: int = DEFAULT_BLOCK_ROWS,
                            interpret: bool = True
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """MVR variant; all array args (R, 128) float32."""
    rows = grad_new.shape[0]
    grid, in_specs, out_specs = _grid_specs(rows, block_rows, 3, 5)
    shape = jax.ShapeDtypeStruct(grad_new.shape, grad_new.dtype)
    dt = grad_new.dtype
    return pl.pallas_call(
        _dasha_mvr_update_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=(shape, shape, shape),
        interpret=interpret,
    )(jnp.full((1,), a, dt), jnp.full((1,), b, dt), jnp.full((1,), scale, dt),
      grad_new, grad_old, h, g_local, mask)


# ---------------------------------------------------------------------------
# row-wise stochastic quantizer (QSGD / QDither compressor)
# ---------------------------------------------------------------------------

def _quantize_kernel(levels_ref, x_ref, u_ref, out_ref):
    s = levels_ref[0]
    x = x_ref[...].astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    safe = jnp.where(norm > 0, norm, 1.0)
    y = jnp.abs(x) / safe * s
    lo = jnp.floor(y)
    q = lo + (u_ref[...] < (y - lo)).astype(jnp.float32)
    out = jnp.sign(x) * q * safe / s
    out_ref[...] = jnp.where(norm > 0, out, 0.0).astype(out_ref.dtype)


def quantize_pallas(x: jax.Array, u: jax.Array, levels: int, *,
                    block_rows: int = 256, interpret: bool = True
                    ) -> jax.Array:
    """Row-quantize x: (R, C) with external uniforms u: (R, C).

    The row (= quantization group) must fit one block, so blocks are
    (block_rows, C) and the grid walks rows only.
    """
    rows, cols = x.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    tens = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    scal = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[scal, tens, tens],
        out_specs=tens,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(jnp.full((1,), levels, jnp.float32), x, u)
