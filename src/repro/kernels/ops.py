"""jit'd public wrappers around the Pallas kernels.

Handles the (d,) <-> (R, 128) padding/reshape plumbing so callers pass flat
vectors (or any shape); kernels see lane-aligned 2-D blocks.  On this CPU
container every call runs with ``interpret=True`` (the kernel body executes
in Python), on a real TPU the same code path compiles to Mosaic.
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dasha_update import (LANE, dasha_mvr_update_pallas,
                                        dasha_update_pallas, quantize_pallas)

#: interpret-mode switch: REPRO_PALLAS_INTERPRET=0 on real TPUs compiles the
#: kernels to Mosaic; any other value (or unset) runs the Python interpreter
#: path, which is what this CPU container supports.
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1").lower() \
    not in ("0", "false", "no")


def _to_lanes(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1).astype(jnp.float32)
    d = flat.shape[0]
    pad = (-d) % LANE
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANE), d


def _from_lanes(x2: jax.Array, d: int, shape, dtype) -> jax.Array:
    return x2.reshape(-1)[:d].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("a", "scale"))
def dasha_update(grad: jax.Array, h: jax.Array, g_local: jax.Array,
                 mask: jax.Array, a: float, scale: float
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused DASHA update on arbitrary-shape tensors (see kernel docstring).

    Returns (m, h_new, g_local_new) with the input shape/dtype.
    """
    shape, dtype = grad.shape, grad.dtype
    g2, d = _to_lanes(grad)
    h2, _ = _to_lanes(h)
    gl2, _ = _to_lanes(g_local)
    mk2, _ = _to_lanes(mask)
    m, hn, gln = dasha_update_pallas(g2, h2, gl2, mk2, a, scale,
                                     interpret=INTERPRET)
    def back(t):
        return _from_lanes(t, d, shape, dtype)

    return back(m), back(hn), back(gln)


@functools.partial(jax.jit, static_argnames=("a", "b", "scale"))
def dasha_mvr_update(grad_new: jax.Array, grad_old: jax.Array, h: jax.Array,
                     g_local: jax.Array, mask: jax.Array, a: float, b: float,
                     scale: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    shape, dtype = grad_new.shape, grad_new.dtype
    gn2, d = _to_lanes(grad_new)
    go2, _ = _to_lanes(grad_old)
    h2, _ = _to_lanes(h)
    gl2, _ = _to_lanes(g_local)
    mk2, _ = _to_lanes(mask)
    m, hn, gln = dasha_mvr_update_pallas(gn2, go2, h2, gl2, mk2, a, b, scale,
                                         interpret=INTERPRET)
    def back(t):
        return _from_lanes(t, d, shape, dtype)

    return back(m), back(hn), back(gln)


@functools.partial(jax.jit, static_argnames=("accumulate", "use_kernel"))
def slab_writeback(full: jax.Array, idx: jax.Array, rows: jax.Array, *,
                   accumulate: bool = False,
                   use_kernel: bool | None = None) -> jax.Array:
    """Write a chunk slab back into the persistent (n, d) store.

    ``idx`` (U,) int32 — sorted-unique global row ids padded with the
    sentinel ``n`` (dropped); ``rows`` (U, d) — the slab.  On compiled
    (non-interpret) backends this is the aliased Pallas kernel
    (:mod:`repro.kernels.slab_writeback`): the store is donated and
    mutated in place.  Under ``REPRO_PALLAS_INTERPRET`` (this CPU
    container) the default is XLA's drop-mode scatter — running the
    interpreter per chunk would serialize U python iterations — and the
    kernel stays covered by passing ``use_kernel=True`` in the unit
    tests.  Both paths produce identical bytes (same update, same drop
    semantics), so store contents never depend on the dispatch."""
    from repro.kernels.slab_writeback import (DEFAULT_BLOCK_ROWS,
                                              slab_writeback_pallas)
    if use_kernel is None:
        use_kernel = not INTERPRET
    if not use_kernel:
        if accumulate:
            return full.at[idx].add(rows, mode="drop")
        return full.at[idx].set(rows, mode="drop")
    n = full.shape[0]
    u = idx.shape[0]
    block = min(DEFAULT_BLOCK_ROWS, u)
    pad = (-u) % block
    idx = jnp.pad(idx, (0, pad), constant_values=n)
    rows = jnp.pad(rows, ((0, pad), (0, 0)))
    return slab_writeback_pallas(full, idx, rows, accumulate=accumulate,
                                 block_rows=block, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("levels",))
def quantize(x: jax.Array, key: jax.Array, levels: int = 15) -> jax.Array:
    """Unbiased row-wise stochastic quantization of x: (R, C)."""
    u = jax.random.uniform(key, x.shape, jnp.float32)
    return quantize_pallas(x, u, levels, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("levels",))
def quantize_with_u(x: jax.Array, u: jax.Array, levels: int = 15
                    ) -> jax.Array:
    """Row-wise quantization with EXTERNAL uniforms (the compress plan layer
    draws them once so dense and fused backends dither identically)."""
    return quantize_pallas(x, u, levels, interpret=INTERPRET)


def ssd_chunk_scan(x: jax.Array, dt: jax.Array, A: jax.Array, b: jax.Array,
                   c: jax.Array, D: jax.Array, chunk: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Pallas-kernel SSD forward (drop-in for models.ssm.ssd_chunked).

    x: (B,S,H,P), dt: (B,S,H), A: (H,), b/c: (B,S,N), D: (H,).
    Intra-chunk blocks run in the Pallas kernel; the O(S/chunk) inter-chunk
    recurrence is a lax.scan; the off-diagonal combine is two einsums.
    """
    from repro.kernels.ssd_chunk import ssd_chunk_pallas

    B, S, H, P = x.shape
    N = b.shape[-1]
    nc = S // chunk
    G = B * H
    # flatten (batch, head) -> G; broadcast per-batch b/c across heads
    xg = (jnp.moveaxis(x, 2, 1)               # (B,H,S,P)
          .reshape(G, nc, chunk, P))
    dtg = jnp.moveaxis(dt, 2, 1).reshape(G, nc, chunk)
    Ag = jnp.broadcast_to(A[None], (B, H)).reshape(G)
    bg = jnp.broadcast_to(b[:, None], (B, H, S, N)).reshape(G, nc, chunk, N)
    cg = jnp.broadcast_to(c[:, None], (B, H, S, N)).reshape(G, nc, chunk, N)

    y_diag, states, decays, acs = ssd_chunk_pallas(
        xg, dtg, Ag, bg, cg, interpret=INTERPRET)

    def scan_fn(s, inp):
        st, dk = inp                               # (G,N,P), (G,)
        out = s
        s = s * dk[:, None, None] + st
        return s, out

    init = jnp.zeros((G, N, P), jnp.float32)
    final, prev = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(decays, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                # (G,nc,N,P)

    # off-diagonal: y_off[q] = exp(acs[q]) * (c[q] @ prev_state)
    y_off = jnp.exp(acs)[..., None] * jnp.einsum("gnqs,gnsp->gnqp", cg,
                                                 prev)
    yg = y_diag + y_off + xg.astype(jnp.float32) \
        * jnp.broadcast_to(D[None], (B, H)).reshape(G)[:, None, None, None]
    y = jnp.moveaxis(yg.reshape(B, H, S, P), 1, 2).astype(x.dtype)
    final_state = final.reshape(B, H, N, P)
    return y, final_state
