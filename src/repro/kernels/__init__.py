# Pallas TPU kernels for the paper's compute hot-spots, validated against
# pure-jnp oracles via interpret=True on CPU:
#  * dasha_update.py — fused DASHA estimator update + compression (GD-like
#    and MVR variants) + a row-wise QSGD quantizer; ops.py wrappers are
#    drop-ins for the optimizer hot loop.
#  * ssd_chunk.py — Mamba2/SSD intra-chunk linear-attention block (the
#    [ssm]/[hybrid] archs' training hot-spot); ops.ssd_chunk_scan is a
#    drop-in for models.ssm.ssd_chunked.
from repro.kernels import dasha_update, ops, ref, ssd_chunk  # noqa: F401
from repro.kernels.ops import (dasha_mvr_update, quantize,  # noqa: F401
                               ssd_chunk_scan)
from repro.kernels.ops import dasha_update as fused_dasha_update  # noqa: F401
