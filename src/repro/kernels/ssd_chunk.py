"""Pallas TPU kernel: Mamba2/SSD intra-chunk block (state-space duality).

The SSD training/prefill pass (models/ssm.py `ssd_chunked`) splits the
sequence into chunks; per chunk the heavy work is attention-like:

    L      = exp(segsum(a))               (Q, Q) lower-triangular decays
    y_diag = (L * (c @ b^T)) @ x_dt       intra-chunk output
    state  = b^T @ (decay_end * x_dt)     chunk's contribution to the
                                          inter-chunk recurrence

This is exactly one (Q=chunk)-square block of a linear-attention kernel —
the natural Pallas unit: grid over (batch*heads, n_chunks), each program
holds one chunk's (Q,N)/(Q,P)/(Q,Q) tiles in VMEM (Q=256, N,P<=128 =>
~1.3 MB working set, MXU-aligned when Q,N,P are multiples of 128/8).

The O(n_chunks) inter-chunk recurrence stays a lax.scan outside the kernel
(sequential by construction); ops.ssd_chunk_scan composes both and matches
models/ssm.ssd_chunked (the oracle) to float tolerance.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, decay_ref, acs_ref):
    """One (batch*head, chunk) block.

    Block shapes (leading grid dims are 1): x (1,1,Q,P); dt (1,1,Q);
    a (1,); b/c (1,1,Q,N).  Outputs: y (1,1,Q,P) intra-chunk part,
    state (1,1,N,P) chunk contribution, decay (1,1) chunk total decay,
    acs (1,1,Q) inclusive cumulative log-decay (for the combine step).
    """
    x = x_ref[0, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)            # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)
    A = a_ref[0]

    xdt = x * dt[:, None]                          # (Q, P)
    a = dt * A                                     # (Q,) log decays
    acs = jnp.cumsum(a)                            # inclusive
    # L[q, k] = exp(acs[q] - acs[k]) for q >= k else 0
    diff = acs[:, None] - acs[None, :]
    q = a.shape[0]
    tri = jnp.tril(jnp.ones((q, q), jnp.bool_))
    L = jnp.where(tri, jnp.exp(diff), 0.0)         # (Q, Q)
    scores = c @ b.T                               # (Q, Q)
    y_ref[0, 0] = ((L * scores) @ xdt).astype(y_ref.dtype)

    decay_end = jnp.exp(acs[-1] - acs)             # (Q,)
    state_ref[0, 0] = (b.T @ (decay_end[:, None] * xdt)).astype(
        state_ref.dtype)
    decay_ref[0, 0] = jnp.exp(acs[-1])
    acs_ref[0, 0] = acs.astype(acs_ref.dtype)


def ssd_chunk_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                     b: jax.Array, c: jax.Array, *, interpret: bool = True
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched intra-chunk pass.

    x: (G, nc, Q, P) where G = batch*heads; dt: (G, nc, Q); A: (G,);
    b/c: (G, nc, Q, N).  Returns (y_diag, chunk_states, chunk_decays, acs)
    with shapes ((G,nc,Q,P), (G,nc,N,P), (G,nc), (G,nc,Q)).
    """
    G, nc, Q, P = x.shape
    N = b.shape[-1]
    f32 = jnp.float32
    grid = (G, nc)
    def t4(d):
        return pl.BlockSpec((1, 1, Q, d), lambda i, j: (i, j, 0, 0))


    t3 = pl.BlockSpec((1, 1, Q), lambda i, j: (i, j, 0))
    ta = pl.BlockSpec((1,), lambda i, j: (i,))

    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[t4(P), t3, ta, t4(N), t4(N)],
        out_specs=(t4(P),
                   pl.BlockSpec((1, 1, N, P), lambda i, j: (i, j, 0, 0)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j)),
                   t3),
        out_shape=(jax.ShapeDtypeStruct((G, nc, Q, P), f32),
                   jax.ShapeDtypeStruct((G, nc, N, P), f32),
                   jax.ShapeDtypeStruct((G, nc), f32),
                   jax.ShapeDtypeStruct((G, nc, Q), f32)),
        interpret=interpret,
    )(x, dt, A, b, c)
