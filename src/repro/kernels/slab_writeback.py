"""Pallas kernel: in-place chunk-slab writeback into the persistent store.

The chunk-resident cohort store (DESIGN.md §16) runs each chunk's scan with
only the compact (U, d) slab of touched client rows in the carry, then
writes the slab back into the persistent (n, d) array ONCE per chunk.  On
backends with buffer donation this writeback should be truly in-place —
an O(U·d) scatter into the existing store, not an O(n·d) copy-and-update
— which is exactly what ``input_output_aliases`` expresses: the (n, d)
store is operand 0 AND output 0, the kernel mutates only the addressed
rows, and every unaddressed row keeps its bytes because the output buffer
IS the input buffer.

Index contract: ``idx`` holds each slab row's global client id, sorted
unique, padded to a static length with the sentinel ``n`` (one past the
last valid row).  Sentinel rows are dropped by a ``pl.when`` guard, so the
caller can keep shapes static across chunks regardless of how many rows a
chunk actually touched.  ``accumulate=True`` switches the row store to a
read-add-write (scatter-accumulate), for callers that fold partial slabs.

Tiling: the grid walks the slab in ``block_rows`` blocks; the store block
is the whole (n, d) array (rows are addressed dynamically via ``pl.ds``).
That holds the store in VMEM on accelerator backends — fine for the
(U ≤ R·C) slabs this repo ships, and the interpret path (this CPU
container, ``REPRO_PALLAS_INTERPRET``) has no such limit.  The production
CPU writeback goes through XLA's scatter in :func:`repro.kernels.ops.
slab_writeback`; this kernel is the accelerator path and is covered in
interpret mode by tests/test_slab_store.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def slab_writeback_pallas(full: jax.Array, idx: jax.Array, rows: jax.Array,
                          *, accumulate: bool = False,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = True) -> jax.Array:
    """Scatter ``rows`` (U, d) into ``full`` (n, d) at ``idx`` (U,) int32.

    ``idx`` entries equal to ``n`` (the pad sentinel) are dropped; U must
    be a multiple of ``block_rows`` (the ops wrapper pads).  Returns the
    updated store, aliased onto the ``full`` operand.
    """
    n, d = full.shape
    u = idx.shape[0]
    block_rows = min(block_rows, u)
    if u % block_rows:
        raise ValueError(f"slab length {u} not a multiple of block_rows "
                         f"{block_rows} — pad with the sentinel {n}")

    def kernel(full_ref, idx_ref, rows_ref, out_ref):
        del full_ref  # aliased: out_ref already holds the store's bytes
        for j in range(block_rows):
            i = idx_ref[j]

            @pl.when(i < n)
            def _store(j=j, i=i):
                row = rows_ref[pl.ds(j, 1), :]
                if accumulate:
                    cur = pl.load(out_ref, (pl.ds(i, 1), slice(None)))
                    pl.store(out_ref, (pl.ds(i, 1), slice(None)), cur + row)
                else:
                    pl.store(out_ref, (pl.ds(i, 1), slice(None)), row)

    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(u, block_rows),),
        in_specs=[pl.BlockSpec((n, d), lambda i: (0, 0)),
                  pl.BlockSpec((block_rows,), lambda i: (i,)),
                  pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(full.shape, full.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(full, idx, rows)
